"""Cost-model laws + replay parity for the pluggable CostModel layer (PR 4).

Four contract groups:

* REGISTRY + LAWS — for every registered model on a grid of environments:
  packed <= unpacked whenever alpha <= 1, costs non-negative, monotone in
  size and duration, and the batched hooks equal the per-event scalar path.
* TABLE1 BIT-COMPAT — a frozen per-request scalar oracle written against the
  pre-PR ``CostParams`` formulas reproduces the engine's ``table1`` replay
  EXACTLY at batch_size=1 (the engine's scalar-order guarantee) and at 1e-9
  under default batching, on the fig5-style paper trace grid.
* PER-SERVER-DT PARITY — the heterogeneous model's batched replay (general
  segment-max anchor path) matches a per-request scalar oracle at 1e-9 for
  every chunking the session tests exercise (1, 7, 4096, ragged).
* BREAKDOWN/TRACE HYGIENE — CostBreakdown.merge refuses cross-model sums;
  Trace validation raises ValueError (not bare asserts).
"""
import dataclasses
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CacheEnvironment,
    CacheSession,
    CostBreakdown,
    CostParams,
    competitive_bound_corrected,
    competitive_bound_env,
    get_cost_model,
    get_policy,
    list_cost_models,
    run_policy,
)
from repro.core.cliques import CliquePartition
from repro.core.engine import ReplayEngine
from repro.traces import SynthConfig, Trace, paper_trace, synth_trace

MODELS = ("table1", "tiered", "heterogeneous")


def make_env(n=24, m=6, alpha=0.8, rho=1.0, price_sigma=0.0, size_sigma=0.0,
             seed=0):
    return CacheEnvironment.skewed(
        n, m, CostParams(alpha=alpha, rho=rho),
        price_sigma=price_sigma, size_sigma=size_sigma, seed=seed)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_lists_shipped_models():
    names = list_cost_models()
    for required in MODELS:
        assert required in names
    with pytest.raises(KeyError):
        get_cost_model("nope_not_a_model")


def test_unbound_model_raises():
    one = np.ones(1, dtype=np.int64)
    for name in MODELS:
        m = get_cost_model(name)
        with pytest.raises(RuntimeError):
            m.dt()
        with pytest.raises(RuntimeError):
            m.transfer_cost_batch(one, np.ones(1), one * 0)
        with pytest.raises(RuntimeError):
            m.caching_rate(one, np.ones(1), one * 0)


def test_akpc_config_plus_env_uses_env_params():
    """A config's untouched default params must not clash with an explicit
    env (env.params drives the algorithm unless params= is passed)."""
    from repro.core import AKPCConfig

    tr = _sized_trace(1000)
    env = CacheEnvironment.skewed(tr.n, tr.m, CostParams(alpha=0.5),
                                  price_sigma=0.5, seed=1)
    res = run_policy(get_policy(
        "akpc", config=AKPCConfig(t_cg=0.73, top_frac=1.0), env=env,
        cost_model="heterogeneous"), tr)
    assert res.costs.model == "heterogeneous"
    assert res.config.params == env.params
    # ...but a CUSTOMIZED config params conflicting with env is refused
    with pytest.raises(ValueError):
        get_policy("akpc",
                   config=AKPCConfig(params=CostParams(alpha=0.3), t_cg=0.73),
                   env=env)


def test_engine_rejects_conflicting_params_and_env():
    """Explicit params that disagree with env.params must not be silently
    ignored (the model prices via env.params)."""
    env = CacheEnvironment(n=8, m=2, params=CostParams(alpha=0.8))
    with pytest.raises(ValueError):
        ReplayEngine(8, 2, CostParams(alpha=0.3), env=env)
    ReplayEngine(8, 2, CostParams(alpha=0.8), env=env)      # equal: fine


def test_shared_model_instance_is_copied_on_rebind():
    """One CostModel instance across two engines must not repoint the first
    engine's pricing arrays."""
    e1 = make_env(price_sigma=0.5, seed=1)
    e2 = make_env(price_sigma=0.5, seed=2)
    m = get_cost_model("heterogeneous", e1)
    dt1 = m.dt().copy()
    m2 = get_cost_model(m, e2)
    assert m2 is not m
    assert np.array_equal(m.dt(), dt1)          # original still on env 1
    assert not np.array_equal(m2.dt(), dt1)


def test_skewed_axes_are_independent():
    """Sweeping price_sigma must not move the item sizes (and vice versa)."""
    a = CacheEnvironment.skewed(12, 4, price_sigma=0.0, size_sigma=0.75, seed=0)
    b = CacheEnvironment.skewed(12, 4, price_sigma=0.5, size_sigma=0.75, seed=0)
    assert np.array_equal(a.item_sizes, b.item_sizes)
    c = CacheEnvironment.skewed(12, 4, price_sigma=0.5, size_sigma=0.0, seed=0)
    assert np.array_equal(b.lam_j, c.lam_j) and np.array_equal(b.mu_j, c.mu_j)


def test_run_policy_threads_trace_sizes_into_price_only_env():
    """Offline driver fills a size-less env from the trace — and matches
    streaming, which does the same when given the trace."""
    tr = _sized_trace(2000)
    params = CostParams()
    mk = lambda: get_policy(
        "akpc", params=params, t_cg=0.73, top_frac=1.0,
        env=CacheEnvironment.skewed(tr.n, tr.m, params, price_sigma=1.0,
                                    seed=4),
        cost_model="heterogeneous")
    off = run_policy(mk(), tr)
    assert off.costs.model == "heterogeneous"
    sized_env = CacheEnvironment.from_trace(
        tr, params, lam_j=mk().env.lam_j, mu_j=mk().env.mu_j)
    explicit = run_policy(get_policy(
        "akpc", params=params, t_cg=0.73, top_frac=1.0, env=sized_env,
        cost_model="heterogeneous"), tr)
    assert off.costs.as_dict() == explicit.costs.as_dict()
    sess = CacheSession(mk(), tr.n, tr.m, trace=tr)
    sess.feed_trace(tr, chunk_size=333)
    assert np.isclose(sess.costs.total, off.costs.total, rtol=1e-9)


def test_environment_validation():
    with pytest.raises(ValueError):
        CacheEnvironment(n=4, m=2, lam_j=np.ones(3))          # wrong shape
    with pytest.raises(ValueError):
        CacheEnvironment(n=4, m=2, mu_j=np.array([1.0, -1.0]))  # negative
    with pytest.raises(ValueError):
        CacheEnvironment(n=4, m=2, item_sizes=np.zeros(4))      # zero sizes


# ---------------------------------------------------------------------------
# model laws (every registered model, environment grid)
# ---------------------------------------------------------------------------
@given(st.integers(1, 12), st.floats(0.05, 1.0),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0), st.integers(0, 5))
@settings(max_examples=12)
def test_packed_leq_unpacked_and_nonneg(p, alpha, psig, ssig, server):
    env = make_env(alpha=alpha, price_sigma=psig, size_sigma=ssig, seed=p)
    for name in MODELS:
        model = get_cost_model(name, env)
        sizes = env.sizes()[:p]
        packed = model.transfer_cost(p, packed=True, sizes=sizes, server=server)
        unpacked = model.transfer_cost(p, packed=False, sizes=sizes,
                                       server=server)
        assert packed >= 0.0 and unpacked >= 0.0, name
        assert packed <= unpacked + 1e-9 * max(1.0, unpacked), name
        assert model.caching_cost(p, 1.0, sizes=sizes, server=server) >= 0.0


@given(st.floats(0.1, 5.0), st.floats(0.1, 5.0), st.floats(0.05, 4.0),
       st.integers(0, 5))
@settings(max_examples=12)
def test_monotone_in_size_and_duration(v1, dv, dur, server):
    env = make_env(price_sigma=0.7, size_sigma=0.5, seed=3)
    for name in MODELS:
        model = get_cost_model(name, env)
        j = np.array([server], dtype=np.int64)
        one = np.array([1], dtype=np.int64)
        lo = model.transfer_cost_batch(one, np.array([v1]), j)[0]
        hi = model.transfer_cost_batch(one, np.array([v1 + dv]), j)[0]
        assert hi >= lo - 1e-12, name              # transfer monotone in size
        r_lo = model.caching_rate(one, np.array([v1]), j)[0]
        r_hi = model.caching_rate(one, np.array([v1 + dv]), j)[0]
        assert r_hi >= r_lo - 1e-12, name          # rent monotone in size
        c1 = model.caching_cost(1, dur, sizes=np.array([v1]), server=server)
        c2 = model.caching_cost(1, 2.0 * dur, sizes=np.array([v1]),
                                server=server)
        assert c2 >= c1 - 1e-12, name              # rent monotone in duration


@pytest.mark.parametrize("name", MODELS)
def test_batched_hooks_equal_scalar_path(name):
    """transfer_cost_batch/caching_rate of E events == E singleton calls."""
    env = make_env(n=40, m=8, price_sigma=0.9, size_sigma=0.8, seed=11)
    model = get_cost_model(name, env)
    rng = np.random.default_rng(5)
    E = 64
    counts = rng.integers(1, 6, E)
    sizes = rng.uniform(0.2, 8.0, E)
    servers = rng.integers(0, env.m, E)
    tb = model.transfer_cost_batch(counts, sizes, servers)
    rb = model.caching_rate(counts, sizes, servers)
    for e in range(E):
        one = model.transfer_cost_batch(
            counts[e : e + 1], sizes[e : e + 1], servers[e : e + 1])
        assert one.shape == (1,) and one[0] == tb[e]
        rone = model.caching_rate(
            counts[e : e + 1], sizes[e : e + 1], servers[e : e + 1])
        assert rone[0] == rb[e]


def test_table1_matches_costparams_formulas():
    """The table1 model IS the pre-PR CostParams arithmetic."""
    for mode in ("consistent", "paper_literal"):
        p = CostParams(lam=1.7, mu=0.6, rho=2.0, alpha=0.45, cost_mode=mode)
        env = CacheEnvironment(n=10, m=4, params=p)
        model = get_cost_model("table1", env)
        assert np.all(model.dt() == p.dt)
        for k in range(0, 8):
            assert model.transfer_cost(k, packed=True) == \
                p.transfer_cost(k, packed=True)
            assert model.transfer_cost(k, packed=False) == \
                p.transfer_cost(k, packed=False)
            assert model.caching_cost(k, 1.3) == p.caching_cost(k, 1.3)


def test_tiered_default_is_table1_on_unit_sizes():
    """Table I == the alpha-linear special case of the tiered model."""
    env = CacheEnvironment(n=10, m=3, params=CostParams(alpha=0.8))
    t1 = get_cost_model("table1", env)
    td = get_cost_model("tiered", env)
    for k in range(1, 9):
        assert math.isclose(td.transfer_cost(k, packed=True),
                            t1.transfer_cost(k, packed=True), rel_tol=1e-12)
        assert math.isclose(td.transfer_cost(k, packed=False),
                            t1.transfer_cost(k, packed=False), rel_tol=1e-12)


def test_tiered_rejects_convex_schedules():
    env = CacheEnvironment(n=4, m=2)
    with pytest.raises(ValueError):
        get_cost_model("tiered", env, breaks=(1.0,), rates=(0.5, 1.0))
    with pytest.raises(ValueError):
        get_cost_model("tiered", env, breaks=(2.0, 1.0), rates=(1, 1, 1))


# ---------------------------------------------------------------------------
# per-request scalar oracle (frozen Alg. 5/6 with per-server dt)
# ---------------------------------------------------------------------------
def fixed_partition(n: int, w: int = 4) -> CliquePartition:
    return CliquePartition.from_cliques(
        n, [tuple(range(i, min(i + w, n))) for i in range(0, n, w)])


def oracle_replay(trace, env, model_name, part, caching_charge="requested"):
    """Per-request Python replay of Alg. 5/6.  For ``table1`` all arithmetic
    goes through the PRE-PR ``CostParams`` formulas; other models use their
    scalar hooks.  Returns a plain dict of the cost accumulators + state."""
    model = get_cost_model(model_name, env)
    params = env.params
    dt = model.dt()
    s_item = env.sizes()
    cnt = np.array([len(c) for c in part.cliques], dtype=np.int64)
    csz = np.array([s_item[list(c)].sum() for c in part.cliques])
    E = np.zeros((part.k, env.m))
    anchor = np.full(part.k, -1, dtype=np.int64)
    T = C = RENT = 0.0
    n_miss = 0

    def rate(nc, sz, j):
        if model_name == "table1":
            return nc * params.mu                      # pre-PR formula
        return float(model.caching_rate(
            np.array([nc]), np.array([sz]), np.array([j]))[0])

    def transfer(c, j):
        if model_name == "table1":                     # pre-PR formula
            return params.transfer_cost(int(cnt[c]), packed=cnt[c] > 1)
        return float(model.transfer_cost_batch(
            cnt[c : c + 1], csz[c : c + 1], np.array([j]))[0])

    for i in range(trace.n_requests):
        t = float(trace.times[i])
        j = int(trace.servers[i])
        ds = trace.items[i][trace.items[i] >= 0]
        if ds.size == 0:
            continue
        cls, counts = np.unique(part.clique_of[ds], return_counts=True)
        # per-request partial sums, merged into the accumulators afterwards
        # — the engine's float summation order (tc.sum() per handle_batch)
        t_r = c_r = rent_r = 0.0
        for c, nreq in zip(cls.tolist(), counts.tolist()):
            dtj = float(dt[j])
            e = float(E[c, j])
            fresh = e > t
            anch = anchor[c] == j and e > 0.0
            if fresh:
                e_eff = e
            elif anch:                                 # Alg. 6 ratchet
                steps = np.ceil((t - e) / dtj)
                r = e + steps * dtj
                if r <= t:
                    r += dtj
                e_eff = r
                rent_r += rate(int(cnt[c]), float(csz[c]), j) * (e_eff - e)
            else:                                      # miss
                e_eff = t
                t_r += transfer(c, j)
                n_miss += 1
            if caching_charge == "requested":
                rq = float(s_item[ds[part.clique_of[ds] == c]].sum())
                rr = rate(nreq, rq, j)
            else:
                rr = rate(int(cnt[c]), float(csz[c]), j)
            c_r += rr * max((t + dtj) - max(e_eff, t), 0.0)
            E[c, j] = t + dtj
            if anchor[c] < 0 or t + dtj >= E[c, anchor[c]]:
                anchor[c] = j
        T += t_r
        C += c_r
        RENT += rent_r
    return dict(transfer=T, caching=C, keepalive_rent=RENT,
                n_misses=n_miss, E=E, anchor=anchor)


def _sized_trace(n_requests=5000, m=9, seed=3, size_dist="lognormal"):
    return synth_trace(SynthConfig(
        kind="netflix", n_items=48, n_servers=m, n_requests=n_requests,
        t_max=24.0, bundle_cover=1.0, bundle_zipf=0.7, seed=seed,
        size_dist=size_dist))


@pytest.mark.parametrize("kind", ["netflix", "spotify"])
def test_table1_replay_bit_identical_to_costparams_oracle(kind):
    """fig5 trace grid: engine(table1, batch_size=1) == the pre-PR scalar
    CostParams replay EXACTLY; default batching at 1e-9."""
    tr = paper_trace(kind, n_requests=4000)
    env = CacheEnvironment.from_trace(tr, CostParams())
    part = fixed_partition(tr.n)
    want = oracle_replay(tr, env, "table1", part)

    eng = ReplayEngine(tr.n, tr.m, env.params, env=env, cost_model="table1")
    eng.install_partition(part, now=0.0)
    eng.replay(tr, batch_size=1)
    assert eng.costs.transfer == want["transfer"]          # bit-for-bit
    assert eng.costs.caching == want["caching"]
    assert eng.costs.keepalive_rent == want["keepalive_rent"]
    assert eng.costs.n_misses == want["n_misses"]
    assert np.array_equal(eng.state.E, want["E"])
    assert np.array_equal(eng.state.anchor, want["anchor"])

    batched = ReplayEngine(tr.n, tr.m, env.params, env=env, cost_model="table1")
    batched.install_partition(part, now=0.0)
    batched.replay(tr)
    for f in ("transfer", "caching", "keepalive_rent"):
        assert np.isclose(getattr(batched.costs, f), want[f], rtol=1e-9)


def test_default_run_is_table1_bit_for_bit():
    """cost_model='table1' + explicit env == the undecorated default."""
    tr = _sized_trace(4000)       # has sizes; table1 must ignore them
    pol_a = get_policy("akpc", params=CostParams(), t_cg=0.73, top_frac=1.0)
    pol_b = get_policy("akpc", params=CostParams(), t_cg=0.73, top_frac=1.0,
                       env=CacheEnvironment.from_trace(tr, CostParams()),
                       cost_model="table1")
    a = run_policy(pol_a, tr).costs.as_dict()
    b = run_policy(pol_b, tr).costs.as_dict()
    assert a == b


@pytest.mark.parametrize("chunk", [1, 7, 4096, "ragged"])
def test_heterogeneous_replay_matches_scalar_oracle(chunk):
    """Per-server-dt batched replay == scalar oracle at 1e-9 for every
    chunking the session tests exercise."""
    tr = _sized_trace()
    params = CostParams()
    skew = CacheEnvironment.skewed(tr.n, tr.m, params, price_sigma=0.9, seed=7)
    env = CacheEnvironment(n=tr.n, m=tr.m, params=params,
                           lam_j=skew.lam_j, mu_j=skew.mu_j,
                           item_sizes=tr.sizes)
    part = fixed_partition(tr.n)
    want = oracle_replay(tr, env, "heterogeneous", part)

    pol = get_policy("dp_greedy", params=params, partition=part,
                     env=env, cost_model="heterogeneous")
    sess = CacheSession(pol, tr.n, tr.m)
    assert not sess.engine._dt_const            # the general path is live
    if chunk == "ragged":
        sizes = [1, 3, 13, 77, 501, 2048]
        pos = k = 0
        while pos < tr.n_requests:
            cs = sizes[k % len(sizes)]
            k += 1
            sess.feed(tr.items[pos:pos + cs], tr.servers[pos:pos + cs],
                      tr.times[pos:pos + cs])
            pos += cs
    else:
        sess.feed_trace(tr, chunk_size=chunk)
    for f in ("transfer", "caching", "keepalive_rent"):
        assert np.isclose(getattr(sess.costs, f), want[f],
                          rtol=1e-9, atol=1e-9), f
    assert sess.costs.n_misses == want["n_misses"]
    assert np.allclose(sess.engine.state.E, want["E"], rtol=1e-9)
    assert np.array_equal(sess.engine.state.anchor, want["anchor"])


def test_heterogeneous_streaming_matches_offline_windowed():
    """AKPC with T_CG windows under the heterogeneous model: any chunking
    reproduces the offline driver (same contract as the table1 session
    tests, now on the general anchor path)."""
    tr = _sized_trace(6000)
    params = CostParams()
    env = CacheEnvironment(
        n=tr.n, m=tr.m, params=params,
        lam_j=CacheEnvironment.skewed(tr.n, tr.m, params, 0.8, seed=2).lam_j,
        item_sizes=tr.sizes)
    mk = lambda: get_policy("akpc", params=params, t_cg=0.73, top_frac=1.0,
                            env=env, cost_model="heterogeneous")
    off = run_policy(mk(), tr)
    sess = CacheSession(mk(), tr.n, tr.m)
    sess.feed_trace(tr, chunk_size=509)
    for f in ("transfer", "caching", "keepalive_rent"):
        assert np.isclose(getattr(sess.costs, f), getattr(off.costs, f),
                          rtol=1e-9)
    assert sess.costs.n_misses == off.costs.n_misses


def test_feed_trace_refuses_dropped_sizes():
    """A size-aware session built without the trace's sizes must refuse the
    sized trace instead of silently pricing unit items (streaming would
    diverge from the offline driver)."""
    tr = _sized_trace(500)
    pol = get_policy("akpc", params=CostParams(), t_cg=0.73, top_frac=1.0,
                     cost_model="heterogeneous")
    sess = CacheSession(pol, tr.n, tr.m)          # env derived WITHOUT sizes
    with pytest.raises(ValueError):
        sess.feed_trace(tr, chunk_size=100)
    ok = CacheSession(
        get_policy("akpc", params=CostParams(), t_cg=0.73, top_frac=1.0,
                   cost_model="heterogeneous"),
        tr.n, tr.m, trace=tr)                     # from_trace picks up sizes
    ok.feed_trace(tr, chunk_size=100)
    off = run_policy(
        get_policy("akpc", params=CostParams(), t_cg=0.73, top_frac=1.0,
                   cost_model="heterogeneous"), tr)
    assert np.isclose(ok.costs.total, off.costs.total, rtol=1e-9)


def test_heterogeneous_snapshot_roundtrip_and_model_guard():
    tr = _sized_trace(3000)
    params = CostParams()
    env = CacheEnvironment.skewed(tr.n, tr.m, params, price_sigma=0.7,
                                  size_sigma=0.4, seed=9)
    mk = lambda cm="heterogeneous": CacheSession(
        get_policy("akpc", params=params, t_cg=0.73, top_frac=1.0,
                   env=env, cost_model=cm), tr.n, tr.m)
    half = tr.n_requests // 2
    a = mk()
    a.feed(tr.items[:half], tr.servers[:half], tr.times[:half])
    snap = a.snapshot()
    b = mk().restore(snap)
    for s in (a, b):
        s.feed(tr.items[half:], tr.servers[half:], tr.times[half:])
    assert a.costs.as_dict() == b.costs.as_dict()
    assert a.costs.model == "heterogeneous"
    assert np.array_equal(a.engine.state.E, b.engine.state.E)
    # a session priced under a different model must refuse the snapshot
    with pytest.raises(ValueError):
        mk("table1").restore(snap)


def test_restore_refuses_different_pricing_scenario():
    """Same model name but different CostParams (or tier schedule) is a
    different accounting scenario — restore must refuse it."""
    tr = _sized_trace(400)
    mk = lambda p: CacheSession(
        get_policy("akpc", params=p, t_cg=0.73, top_frac=1.0), tr.n, tr.m)
    a = mk(CostParams(alpha=0.9, lam=5.0))
    a.feed(tr.items[:200], tr.servers[:200], tr.times[:200])
    snap = a.snapshot()
    with pytest.raises(ValueError):
        mk(CostParams(alpha=0.5, lam=1.0)).restore(snap)
    mk(CostParams(alpha=0.9, lam=5.0)).restore(snap)        # same: fine


def test_opt_lower_bound_rejects_unsupported_models():
    from repro.core import opt_lower_bound

    tr = _sized_trace(300)
    with pytest.raises(ValueError):
        opt_lower_bound(tr, CostParams(), cost_model="tiered")
    opt_lower_bound(tr, CostParams(), cost_model="heterogeneous")


def test_opt_lower_bound_table1_ignores_env_prices_like_the_model():
    """table1 pricing ignores env prices, so its lower bound must too —
    otherwise a priced env inflates the 'bound' above achievable costs."""
    from repro.core import opt_lower_bound, run_no_packing

    tr = _sized_trace(2000)
    p = CostParams()
    env = CacheEnvironment(n=tr.n, m=tr.m, params=p,
                           lam_j=np.full(tr.m, 5.0), mu_j=np.full(tr.m, 5.0))
    lb = opt_lower_bound(tr, p, env=env, cost_model="table1").total
    actual = run_no_packing(tr, p, env=env, cost_model="table1").total
    assert lb <= actual
    assert lb == opt_lower_bound(tr, p).total      # same as homogeneous


# ---------------------------------------------------------------------------
# competitive bound generalisation
# ---------------------------------------------------------------------------
def test_bound_env_reduces_to_corrected():
    env = CacheEnvironment(n=10, m=4, params=CostParams(alpha=0.8, rho=1.0))
    for S in (1, 2, 5):
        for omega in (2, 5):
            assert math.isclose(
                competitive_bound_env(env, S, omega),
                competitive_bound_corrected(S, omega, 0.8), rel_tol=1e-12)


def test_bound_env_grows_with_size_skew():
    p = CostParams(alpha=0.8)
    flat = CacheEnvironment(n=10, m=4, params=p)
    skewed = CacheEnvironment(n=10, m=4, params=p,
                              item_sizes=np.linspace(0.5, 2.0, 10))
    assert competitive_bound_env(skewed, 3, 5) > competitive_bound_env(flat, 3, 5)


# ---------------------------------------------------------------------------
# breakdown + trace hygiene
# ---------------------------------------------------------------------------
def test_merge_rejects_cross_model_breakdowns():
    a = CostBreakdown(transfer=1.0, model="table1")
    b = CostBreakdown(transfer=2.0, model="heterogeneous")
    with pytest.raises(ValueError):
        a.merge(b)
    c = CostBreakdown(transfer=2.0, caching=3.0, n_requests=4, model="table1")
    a.merge(c)
    assert a.transfer == 3.0 and a.caching == 3.0 and a.n_requests == 4
    assert a.model == "table1"


def test_merge_sums_every_numeric_field():
    kw = {f.name: 2 for f in dataclasses.fields(CostBreakdown)
          if f.name != "model"}
    a, b = CostBreakdown(**kw), CostBreakdown(**kw)
    a.merge(b)
    for f in dataclasses.fields(CostBreakdown):
        if f.name != "model":
            assert getattr(a, f.name) == 4


def test_trace_validation_raises_valueerror():
    t = np.array([0.0, 1.0])
    sv = np.array([0, 1], dtype=np.int32)
    it = np.zeros((2, 2), dtype=np.int32)
    with pytest.raises(ValueError):
        Trace(times=t, servers=sv[:1], items=it, n=4, m=2)      # bad servers
    with pytest.raises(ValueError):
        Trace(times=t, servers=sv, items=it[:1], n=4, m=2)      # bad items
    with pytest.raises(ValueError):
        Trace(times=t[::-1], servers=sv, items=it, n=4, m=2)    # unsorted
    with pytest.raises(ValueError):
        Trace(times=t, servers=sv, items=it, n=4, m=2,
              sizes=np.array([1.0, 2.0]))                       # wrong shape
    with pytest.raises(ValueError):
        Trace(times=t, servers=sv, items=it, n=4, m=2,
              sizes=np.array([1.0, 0.0, 1.0, 1.0]))             # zero size


def test_trace_sizes_survive_save_load(tmp_path):
    tr = _sized_trace(300)
    assert tr.sizes is not None
    path = str(tmp_path / "t.npz")
    tr.save(path)
    back = Trace.load(path)
    assert np.array_equal(back.sizes, tr.sizes)
    assert back.slice(10, 50).sizes is tr.sizes or \
        np.array_equal(back.slice(10, 50).sizes, tr.sizes)
