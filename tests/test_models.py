"""Model zoo: per-arch smoke (reduced configs) + layer-math equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models.api import build_model
from repro.models.attention import attention_chunked, attention_dense, expand_kv
from repro.models.linear_attn import (
    chunked_linear_attention,
    linear_attention_step,
)


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
             "labels": jnp.array(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.array(rng.normal(size=(B, S, cfg.d_model)),
                                    jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jnp.array(
            rng.normal(size=(B, cfg.vlm.n_patches, cfg.vlm.d_patch)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """Reduced same-family config: one fwd/train step, shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert jnp.isfinite(loss), arch
    gnorm = sum(jnp.sum(jnp.abs(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    cache = model.init_cache(B, S, jnp.bfloat16)
    tok = jnp.zeros((B, 1), jnp.int32)
    dec = jax.jit(model.decode_step)
    for pos in range(3):
        logits, cache = dec(params, cache, tok, jnp.array(pos, jnp.int32))
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.param_count() > 0


def test_full_param_counts_plausible():
    """Full configs land near their advertised sizes."""
    expect = {"deepseek_v2_236b": (200e9, 260e9), "command_r_35b": (30e9, 40e9),
              "qwen2_5_3b": (2.5e9, 3.8e9), "codeqwen1_5_7b": (6e9, 8.5e9),
              "xlstm_125m": (0.1e9, 0.22e9), "h2o_danube_1_8b": (1.4e9, 2.2e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_chunked_attention_matches_dense():
    rng = np.random.default_rng(0)
    B, S, H, D = 2, 128, 4, 16
    q = jnp.array(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.array(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.array(rng.normal(size=(B, S, H, D)), jnp.float32)
    for window in (0, 40):
        dense = attention_dense(q, k, v, causal=True, window=window)
        chunk = attention_chunked(q, k, v, causal=True, window=window, chunk=32)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(chunk),
                                   rtol=2e-4, atol=2e-4)


def test_expand_kv_grouped_equivalence():
    rng = np.random.default_rng(1)
    B, S, KH, G, D = 2, 16, 2, 4, 8
    k = jnp.array(rng.normal(size=(B, S, KH, D)), jnp.float32)
    e = expand_kv(k, KH * G)
    for g in range(G):
        np.testing.assert_array_equal(np.asarray(e[:, :, g::G][:, :, :KH][:, :, 0]),
                                      np.asarray(e[:, :, 0]))
    # group layout: head h maps to kv head h // G
    for h in range(KH * G):
        np.testing.assert_array_equal(np.asarray(e[:, :, h]),
                                      np.asarray(k[:, :, h // G]))


def test_chunked_linear_attention_matches_recurrence():
    """Chunkwise SSD == step-by-step recurrence."""
    rng = np.random.default_rng(2)
    B, S, H, dk, dv, C = 2, 64, 3, 8, 12, 16
    q = jnp.array(rng.normal(size=(B, S, H, dk)), jnp.float32)
    k = jnp.array(rng.normal(size=(B, S, H, dk)), jnp.float32)
    v = jnp.array(rng.normal(size=(B, S, H, dv)), jnp.float32)
    log_f = jnp.array(-np.abs(rng.normal(size=(B, S, H))) * 0.1, jnp.float32)
    ig = jnp.array(rng.random((B, S, H)), jnp.float32)
    y_chunk, state_chunk = chunked_linear_attention(q, k, v, log_f, ig, chunk=C)
    state = jnp.zeros((B, H, dk, dv), jnp.float32)
    ys = []
    for t in range(S):
        yt, state = linear_attention_step(
            state, q[:, t], k[:, t], v[:, t], log_f[:, t], ig[:, t])
        ys.append(yt)
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(state_chunk), np.asarray(state),
                               rtol=1e-3, atol=1e-3)


def test_moe_routing_determinism_and_balance():
    from repro.models.mlp import moe_forward
    from repro.models.common import KeyGen
    from repro.models.mlp import init_moe
    cfg = get_smoke_config("granite_moe_3b_a800m")
    kg = KeyGen(jax.random.PRNGKey(0))
    p = jax.tree.map(lambda a: a[0], init_moe(kg, cfg, 1, jnp.float32))
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y1, aux1 = moe_forward(p, x, cfg)
    y2, aux2 = moe_forward(p, x, cfg)
    assert np.array_equal(np.asarray(y1), np.asarray(y2))
    assert float(aux1) > 0
