"""Oracle parity for the vectorized Clique Generation Module (PR 3).

``repro.core.cliques`` (incremental-merge, array-native) must return
partitions element-for-element identical to ``repro.core.cliques_ref``
(the legacy scalar implementation, frozen as the oracle) — same cliques
in the same index order, same ``clique_of`` — over an
(omega x gamma x theta) grid on netflix/spotify-style synthetic traces,
with windows chained (prev partition + prev CRM) exactly as AKPC runs.
"""
import numpy as np
import pytest

from repro.core import cliques as fast
from repro.core import cliques_ref as ref
from repro.core.cliques import CliquePartition, _CrmView
from repro.core.crm import build_window_crm, edge_diff, edge_diff_arrays
from repro.traces import SynthConfig, synth_trace

N_ITEMS = 48
N_WINDOWS = 3


def _windows(kind: str, seed: int = 0):
    tr = synth_trace(SynthConfig(
        kind=kind, n_items=N_ITEMS, n_servers=10, n_requests=240,
        t_max=12.0, bundle_cover=1.0, seed=seed))
    per = tr.items.shape[0] // N_WINDOWS
    return [tr.items[w * per: (w + 1) * per] for w in range(N_WINDOWS)]


def _assert_identical(a: CliquePartition, b: CliquePartition, ctx: str):
    assert a.cliques == b.cliques, ctx
    assert (a.clique_of == b.clique_of).all(), ctx


@pytest.mark.parametrize("kind", ["netflix", "spotify"])
@pytest.mark.parametrize("omega", [3, 4, 5])
@pytest.mark.parametrize("gamma", [0.6, 0.85, 0.95])
@pytest.mark.parametrize("theta", [0.1, 0.3])
def test_generate_cliques_parity_grid(kind, omega, gamma, theta):
    """Chained windows: new == oracle at every clique-generation event."""
    wins = _windows(kind)
    pf = pr = None
    cf = cr = None
    for w, items in enumerate(wins):
        crm = build_window_crm(items, N_ITEMS, theta, top_frac=0.5)
        nf = fast.generate_cliques(pf, cf, crm, N_ITEMS, omega, gamma)
        nr = ref.generate_cliques(pr, cr, crm, N_ITEMS, omega, gamma)
        _assert_identical(
            nf, nr, f"{kind} omega={omega} gamma={gamma} theta={theta} w={w}"
        )
        pf, cf = nf, crm
        pr, cr = nr, crm


@pytest.mark.parametrize("omega,gamma", [(2, 0.5), (5, 0.4)])
def test_parity_unpruned_regime(omega, gamma):
    """gamma <= (omega-2)/omega or omega <= 2: the edge pruning must stay off."""
    for items in _windows("netflix", seed=7):
        crm = build_window_crm(items, N_ITEMS, 0.1, top_frac=1.0)
        nf = fast.generate_cliques(None, None, crm, N_ITEMS, omega, gamma)
        nr = ref.generate_cliques(None, None, crm, N_ITEMS, omega, gamma)
        _assert_identical(nf, nr, f"omega={omega} gamma={gamma}")


def test_ablation_variant_parity():
    """enable_split / enable_approx_merge combinations match the oracle."""
    wins = _windows("spotify", seed=3)
    for split in (True, False):
        for merge in (True, False):
            pf = pr = None
            cf = cr = None
            for items in wins:
                crm = build_window_crm(items, N_ITEMS, 0.15, top_frac=0.5)
                nf = fast.generate_cliques(
                    pf, cf, crm, N_ITEMS, 5, 0.85,
                    enable_split=split, enable_approx_merge=merge)
                nr = ref.generate_cliques(
                    pr, cr, crm, N_ITEMS, 5, 0.85,
                    enable_split=split, enable_approx_merge=merge)
                _assert_identical(nf, nr, f"split={split} merge={merge}")
                pf, cf = nf, crm
                pr, cr = nr, crm


def test_edge_diff_arrays_matches_sets():
    """Boolean-matrix diff == legacy set diff, rows lexicographically sorted."""
    wins = _windows("netflix", seed=5)
    prev = None
    for items in wins:
        cur = build_window_crm(items, N_ITEMS, 0.1, top_frac=0.4)
        a_set, r_set = edge_diff(prev, cur)
        a_arr, r_arr = edge_diff_arrays(prev, cur)
        assert [tuple(e) for e in a_arr.tolist()] == sorted(a_set)
        assert [tuple(e) for e in r_arr.tolist()] == sorted(r_set)
        prev = cur


def test_pair_edges_kernel_parity_interpret():
    """Pallas clique_density (interpret mode) drives the incremental merge
    to the same partitions as the numpy matmul path."""
    jax = pytest.importorskip("jax")
    del jax
    from repro.kernels.clique_density import clique_pair_edges

    def pair_edges(M, A):
        return np.asarray(clique_pair_edges(M, A, interpret=True))

    items = _windows("spotify", seed=11)[0]
    crm = build_window_crm(items, N_ITEMS, 0.1, top_frac=1.0)
    view = _CrmView(crm, N_ITEMS)
    groups = [(i,) for i in range(N_ITEMS)]
    base = fast.approximate_merge(groups, view, 4, 0.7)
    kern = fast.approximate_merge(groups, view, 4, 0.7, pair_edges=pair_edges)
    orac = ref.approximate_merge(groups, ref._CrmView(crm, N_ITEMS), 4, 0.7)
    assert base == kern == orac
    # and end-to-end through generate_cliques
    a = fast.generate_cliques(None, None, crm, N_ITEMS, 4, 0.7,
                              pair_edges=pair_edges)
    b = ref.generate_cliques(None, None, crm, N_ITEMS, 4, 0.7)
    _assert_identical(a, b, "kernel end-to-end")
