"""Batched replay == scalar replay, cost-for-cost (engine tentpole).

The batched engine's contract (engine.py module docstring): integer counters
are identical to the per-request scalar loop; float costs agree up to
summation order (we assert 1e-9 relative).  ``batch_size=1`` IS the scalar
loop (handle_request is a batch-of-one wrapper), so it serves as the
reference everywhere.
"""
import math

import numpy as np
import pytest

from repro.core import CliquePartition, CostParams, ReplayEngine
from repro.core.baselines import greedy_pair_matching
from repro.kernels.packed_lookup import clique_lookup
from repro.traces import SynthConfig, Trace, batch_tensors, synth_trace

INT_FIELDS = ("n_requests", "n_item_requests", "n_misses", "n_hits",
              "items_transferred")
FLOAT_FIELDS = ("transfer", "caching", "keepalive_rent", "total")


def _trace(n_requests=20000, seed=3, m=20, t_max=40.0):
    return synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=m, n_requests=n_requests,
        t_max=t_max, bundle_cover=1.0, bundle_zipf=0.7, seed=seed))


def _pair_gen(n):
    def gen(w_items, w_servers, now):
        del w_servers, now
        return greedy_pair_matching(w_items, n, theta=0.2, top_frac=1.0)
    return gen


def _replay(tr, batch_size, *, t_cg=None, gen=None, charge="requested",
            install_pairs=False):
    eng = ReplayEngine(tr.n, tr.m, CostParams(), caching_charge=charge)
    if install_pairs:
        eng.install_partition(
            greedy_pair_matching(tr.items, tr.n, 0.2, 1.0), now=0.0)
    eng.replay(tr, clique_generator=gen, t_cg=t_cg, batch_size=batch_size)
    return eng.costs


def assert_same_costs(ref, got, rtol=1e-9):
    a, b = ref.as_dict(), got.as_dict()
    for f in INT_FIELDS:
        assert a[f] == b[f], f"{f}: {a[f]} != {b[f]}"
    for f in FLOAT_FIELDS:
        assert np.isclose(a[f], b[f], rtol=rtol, atol=1e-9), \
            f"{f}: {a[f]} != {b[f]}"


@pytest.mark.parametrize("batch_size", [7, 256, 4096])
def test_batched_matches_scalar_static_partition(batch_size):
    """Packed pair cliques, no regeneration: every CostBreakdown field."""
    tr = _trace()
    ref = _replay(tr, 1, install_pairs=True)
    got = _replay(tr, batch_size, install_pairs=True)
    assert ref.n_misses > 0 and ref.n_hits > 0 and ref.keepalive_rent > 0
    assert_same_costs(ref, got)


@pytest.mark.parametrize("batch_size", [64, 256])
def test_batched_matches_scalar_with_tcg_mid_batch(batch_size):
    """Clique regeneration with T_CG boundaries falling mid-batch.

    t_cg = 0.73 never divides the batch grid, so every Event 1 lands inside
    a would-be batch and must split it at exactly the scalar trigger index.
    """
    tr = _trace(n_requests=12000, seed=11)
    gen = _pair_gen(tr.n)
    ref = _replay(tr, 1, t_cg=0.73, gen=gen)
    got = _replay(tr, batch_size, t_cg=0.73, gen=gen)
    assert_same_costs(ref, got)


def test_batched_matches_scalar_stored_accounting():
    tr = _trace(n_requests=8000, seed=5)
    ref = _replay(tr, 1, charge="stored", install_pairs=True)
    got = _replay(tr, 512, charge="stored", install_pairs=True)
    assert_same_costs(ref, got)


def _single_item_trace(times, servers, n=2, m=3):
    R = len(times)
    items = np.zeros((R, 1), dtype=np.int32)
    return Trace(times=np.asarray(times, np.float64),
                 servers=np.asarray(servers, np.int32), items=items,
                 n=n, m=m, name="crafted")


def test_anchor_handoff_within_one_batch():
    """Alg. 6 anchor moves server mid-batch; later same-batch access to the
    old anchor's lapsed copy must MISS (the nasty cross-server case)."""
    tr = _single_item_trace(
        times=[0.0, 5.0, 5.1, 5.2, 9.0], servers=[0, 1, 0, 1, 0])
    ref = _replay(tr, 1)
    got = _replay(tr, 16)        # the whole trace in one batch
    assert_same_costs(ref, got)
    # miss, miss (anchor at 0), MISS (anchor moved to 1), fresh hit, miss
    assert got.n_misses == 4 and got.n_hits == 1


def test_ratchet_rent_within_one_batch():
    """Lapsed-anchor ratcheting (and its lazily-accounted rent) inside a
    batch: gap 3.7 > dt=1 at the same server ratchets 1.0 -> 4.0."""
    tr = _single_item_trace(times=[0.0, 3.7], servers=[0, 0])
    ref = _replay(tr, 1)
    got = _replay(tr, 4)
    assert_same_costs(ref, got)
    assert got.n_misses == 1 and got.n_hits == 1
    assert math.isclose(got.keepalive_rent, 3.0, rel_tol=1e-12)
    assert math.isclose(got.caching, 1.0 + 0.7, rel_tol=1e-12)


def test_batch_tensors_padding_roundtrip():
    tr = _trace(n_requests=1000, seed=9)
    tb = batch_tensors(tr, 128)
    assert tb.n_batches == 8 and tb.batch_size == 128
    assert int(tb.lengths.sum()) == tr.n_requests
    assert (tb.items[-1, int(tb.lengths[-1]):] == -1).all()
    # padded rows are empty requests: replaying the tensors batch-by-batch
    # gives the same costs as the trace, modulo the padded request count
    eng_t = ReplayEngine(tr.n, tr.m, CostParams())
    for b in range(tb.n_batches):
        eng_t.handle_batch(tb.items[b], tb.servers[b], tb.times[b])
    eng_r = ReplayEngine(tr.n, tr.m, CostParams())
    eng_r.replay(tr, batch_size=128)
    pad = tb.n_batches * tb.batch_size - tr.n_requests
    assert eng_t.costs.n_requests == eng_r.costs.n_requests + pad
    eng_t.costs.n_requests -= pad
    assert_same_costs(eng_r.costs, eng_t.costs)


def test_clique_lookup_pallas_interpret_matches_numpy():
    part = CliquePartition.from_cliques(12, [(0, 1, 2), (5, 6)])
    items = np.array([[0, 5, 11, -1], [2, 6, -1, -1]], dtype=np.int32)
    want = clique_lookup(part.clique_of, items, use_pallas=False)
    got = clique_lookup(part.clique_of, items, use_pallas=True, interpret=True)
    assert (want == np.asarray(got)).all()
    assert (want[items < 0] == -1).all()


@pytest.mark.slow
def test_batched_matches_scalar_100k():
    """Acceptance: cost-for-cost equality on a seeded 100k-request trace."""
    tr = _trace(n_requests=100_000, seed=0, m=50, t_max=200.0)
    gen = _pair_gen(tr.n)
    ref = _replay(tr, 1, t_cg=3.1, gen=gen)
    got = _replay(tr, 4096, t_cg=3.1, gen=gen)
    assert_same_costs(ref, got)
