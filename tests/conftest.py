import numpy as np
import pytest

from repro.core import CostParams
from repro.traces import SynthConfig, synth_trace


@pytest.fixture(scope="session")
def params():
    return CostParams()


@pytest.fixture(scope="session")
def small_trace():
    return synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=20, n_requests=4000,
        t_max=8.0, bundle_cover=1.0, bundle_zipf=0.7, seed=7))
