import pathlib
import sys

try:                # real hypothesis, if installed (requirements-dev.txt)
    import hypothesis  # noqa: F401
except ImportError:  # offline container: deterministic seeded-sweep shim
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    import _hypothesis_compat

    sys.modules["hypothesis"] = _hypothesis_compat

import numpy as np
import pytest

from repro.core import CostParams
from repro.traces import SynthConfig, synth_trace


@pytest.fixture(scope="session")
def params():
    return CostParams()


@pytest.fixture(scope="session")
def small_trace():
    return synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=20, n_requests=4000,
        t_max=8.0, bundle_cover=1.0, bundle_zipf=0.7, seed=7))
