"""AdamW vs numpy reference; schedule; int8 error-feedback compression."""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_gradients,
    cosine_schedule,
    decompress_gradients,
    ef_init,
)


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      clip_norm=1e9, warmup_steps=0, total_steps=10**9,
                      min_lr_frac=1.0)
    p = {"w": jnp.array([[1.0, -2.0]], jnp.float32)}
    state = adamw_init(p)
    g = {"w": jnp.array([[0.5, 0.25]], jnp.float32)}
    m = v = np.zeros((1, 2))
    w = np.array([[1.0, -2.0]])
    for step in range(1, 4):
        p, state, _ = adamw_update(cfg, g, state, p)
        gn = np.array([[0.5, 0.25]])
        m = 0.9 * m + 0.1 * gn
        v = 0.99 * v + 0.01 * gn**2
        mh = m / (1 - 0.9**step)
        vh = v / (1 - 0.99**step)
        w = w - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p["w"]), w, rtol=1e-5)


def test_clipping_and_decay():
    cfg = AdamWConfig(lr=0.1, clip_norm=0.1, weight_decay=0.5,
                      warmup_steps=0, total_steps=10**9, min_lr_frac=1.0)
    p = {"w": jnp.ones((4, 4), jnp.float32)}
    state = adamw_init(p)
    g = {"w": jnp.full((4, 4), 100.0, jnp.float32)}
    p2, state, stats = adamw_update(cfg, g, state, p)
    assert float(stats["grad_norm"]) > 0.1          # raw norm reported
    assert np.all(np.isfinite(np.asarray(p2["w"])))
    assert np.all(np.asarray(p2["w"]) < 1.0)        # decay + update applied


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.array(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0 and math.isclose(lrs[1], 0.5)
    assert math.isclose(lrs[2], 1.0)
    assert lrs[3] < 1.0 and math.isclose(lrs[4], 0.1, rel_tol=1e-5)


def test_cosine_schedule_warmup_floor_default_bitwise():
    """warmup_floor=0.0 (the default) must preserve the original ramp
    BITWISE: floor + (1-floor)*ramp literally adds 0.0 and scales by 1.0."""
    cfg = AdamWConfig(lr=0.37, warmup_steps=13, total_steps=100,
                      min_lr_frac=0.1)
    assert cfg.warmup_floor == 0.0

    def old_schedule(step):                  # the pre-floor formula, verbatim
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(cfg.warmup_steps, 1)
        prog = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)

    for s in range(0, 101, 7):
        step = jnp.array(s)
        assert float(cosine_schedule(cfg, step)) == float(old_schedule(step))


def test_cosine_schedule_warmup_floor_semantics():
    """With a floor f the warmup ramps linearly f*lr -> lr, and the
    post-warmup cosine leg is untouched."""
    f = 0.25
    base = AdamWConfig(lr=2.0, warmup_steps=10, total_steps=100,
                       min_lr_frac=0.1)
    cfg = AdamWConfig(lr=2.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1, warmup_floor=f)
    assert math.isclose(float(cosine_schedule(cfg, jnp.array(0))), f * cfg.lr)
    mid = float(cosine_schedule(cfg, jnp.array(5)))
    assert math.isclose(mid, (f + (1 - f) * 0.5) * cfg.lr, rel_tol=1e-6)
    # floor applies only below warmup_steps
    for s in (10, 55, 100):
        assert float(cosine_schedule(cfg, jnp.array(s))) == float(
            cosine_schedule(base, jnp.array(s)))


def test_error_feedback_compression_reduces_error():
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.array(rng.normal(size=(64,)), jnp.float32)}
    ef = ef_init(g_true)
    acc_q = np.zeros(64)
    acc_t = np.zeros(64)
    for _ in range(50):
        q, ef = compress_gradients(g_true, ef)
        deq = decompress_gradients(q, g_true)
        acc_q += np.asarray(deq["w"])
        acc_t += np.asarray(g_true["w"])
    # error feedback: accumulated quantised gradient tracks the true sum
    rel = np.abs(acc_q - acc_t).max() / np.abs(acc_t).max()
    assert rel < 0.01
