"""Cost model (paper Table I, eqs. 1-5) + competitive bound properties."""
import math

import pytest
from hypothesis import given, strategies as st

from repro.core import CostParams, competitive_bound, competitive_bound_corrected


def test_table1_identities(params):
    assert params.transfer_cost(1, packed=False) == params.lam
    assert params.transfer_cost(1, packed=True) == params.lam
    assert params.transfer_cost(2, packed=False) == 2 * params.lam
    assert math.isclose(params.transfer_cost(2, packed=True),
                        (1 + params.alpha) * params.lam)
    k = 5
    assert math.isclose(params.transfer_cost(k, packed=True),
                        (1 + (k - 1) * params.alpha) * params.lam)
    assert math.isclose(params.caching_cost(k, params.dt), k * params.dt)


def test_dt_rho():
    p = CostParams(lam=3.0, mu=2.0, rho=4.0)
    assert math.isclose(p.dt, 4.0 * 3.0 / 2.0)


@given(st.integers(1, 20), st.integers(2, 10),
       st.floats(0.01, 1.0, allow_nan=False))
def test_packed_always_cheaper(p, omega, alpha):
    cp = CostParams(alpha=alpha)
    assert cp.transfer_cost(p, packed=True) <= cp.transfer_cost(p, packed=False) + 1e-9


def test_paper_literal_mode():
    p = CostParams(cost_mode="paper_literal")
    # Alg. 5 line 11 literal: alpha * mu * |c|
    assert math.isclose(p.transfer_cost(5, packed=True), 0.8 * 1.0 * 5)


@given(st.integers(1, 10), st.integers(2, 12),
       st.floats(0.05, 1.0, allow_nan=False))
def test_corrected_bound_dominates_stated(S, omega, alpha):
    # the stated Thm-1 form drops an S and UNDERSTATES the realised ratio
    assert competitive_bound_corrected(S, omega, alpha) >= \
        competitive_bound(S, omega, alpha) - 1e-9


@given(st.integers(2, 12), st.floats(0.05, 1.0, allow_nan=False))
def test_bounds_agree_at_S1(omega, alpha):
    assert math.isclose(competitive_bound(1, omega, alpha),
                        competitive_bound_corrected(1, omega, alpha))
