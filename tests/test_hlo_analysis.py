"""The HLO cost walker: loop multipliers, dot FLOPs, collective byte math."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo


def test_scan_trip_count_multiplier():
    A = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(a):
        def body(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(body, a, None, length=10)
        return out

    txt = jax.jit(scanned).lower(A).compile().as_text()
    st = analyze_hlo(txt, 1)
    assert abs(st.flops - 10 * 2 * 128**3) / (10 * 2 * 128**3) < 0.01


def test_single_matmul_flops():
    A = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    B = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    txt = jax.jit(lambda a, b: a @ b).lower(A, B).compile().as_text()
    st = analyze_hlo(txt, 1)
    assert st.flops == 2 * 64 * 32 * 16


def test_collective_wire_bytes():
    hlo = """
HloModule m

ENTRY %main.1 (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ar = f32[16,16]{1,0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %ag = f32[16,16]{1,0} all-gather(%ar), replica_groups={{0,1},{2,3},{4,5},{6,7}}, dimensions={0}
}
"""
    st = analyze_hlo(hlo, 8)
    b = 16 * 16 * 4
    assert st.coll_bytes_by_kind["all-reduce"] == 2 * b * 3 / 4
    assert st.coll_bytes_by_kind["all-gather"] == b * 1 / 2
