"""JAX replay backend + vmapped SweepEngine (PR 5 tentpole).

Contracts under test:

* backend parity — ``run_policy(backend="jax")`` reproduces the NumPy
  engine cost-for-cost (1e-9 relative on float sums, integer counters
  exact) for EVERY registered policy, on table1 AND heterogeneous cost
  models, across the PR-2 chunking grid (batch size 1 / 7 / 4096 and a
  ragged mixed-backend session feed);
* sweep parity — ``SweepEngine`` results equal per-point serial
  ``run_policy`` at 1e-9 across all six registered policies and both
  cost models, including when points SHARE a host schedule (alpha sweeps)
  and when a group is replayed in one vmapped device call;
* session interop — a jax ``feed_trace`` syncs state/costs/window
  bookkeeping such that snapshots restore and numpy continuation agree
  with a pure-numpy session;
* backend guard rails — unknown backends and inexpressible cost models
  are refused loudly instead of silently falling back.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import (
    CacheEnvironment,
    CacheSession,
    CostParams,
    SweepEngine,
    SweepPoint,
    get_policy,
    list_policies,
    run_policy,
    sweep_points,
)
from repro.core.cost import CostModel, register_cost_model
from repro.core.engine_jax import run_policy_jax
from repro.traces import SynthConfig, synth_trace

PARAMS = CostParams()
T_CG = 0.73            # never divides the batch grid: windows split batches
TOP_FRAC = 1.0
ALL_POLICIES = ("no_packing", "ttl", "learned", "packcache", "dp_greedy",
                "akpc", "akpc_no_acm", "akpc_base")

INT_FIELDS = ("n_requests", "n_item_requests", "n_misses", "n_hits",
              "items_transferred")
FLOAT_FIELDS = ("transfer", "caching", "keepalive_rent", "total")


def _trace(n_requests=4000, seed=3, m=12, size_dist="unit"):
    return synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=m, n_requests=n_requests,
        t_max=30.0, bundle_cover=1.0, bundle_zipf=0.7, seed=seed,
        size_dist=size_dist))


def _kwargs(name, **extra):
    kw = {"params": PARAMS}
    if name in ("packcache", "akpc", "akpc_no_acm", "akpc_base"):
        kw.update(t_cg=T_CG, top_frac=TOP_FRAC)
    if name in ("ttl", "learned"):     # keep-or-not policies: no packing knobs
        kw.update(t_cg=T_CG)
    if name == "dp_greedy":
        kw.update(top_frac=TOP_FRAC)
    kw.update(extra)
    return kw


def assert_same_costs(ref, got, rtol=1e-9):
    a = ref.as_dict() if not isinstance(ref, dict) else ref
    b = got.as_dict() if not isinstance(got, dict) else got
    for f in INT_FIELDS:
        assert a[f] == b[f], f"{f}: {a[f]} != {b[f]}"
    for f in FLOAT_FIELDS:
        assert np.isclose(a[f], b[f], rtol=rtol, atol=1e-9), \
            f"{f}: {a[f]} != {b[f]}"


@pytest.fixture(scope="module")
def trace():
    return _trace()


@pytest.fixture(scope="module")
def sized_trace():
    return _trace(size_dist="lognormal")


@pytest.fixture(scope="module")
def het_env(sized_trace):
    env = CacheEnvironment.skewed(
        sized_trace.n, sized_trace.m, PARAMS, price_sigma=0.8, seed=1)
    return CacheEnvironment.resolve(env, sized_trace, PARAMS)


# ---------------------------------------------------------------------------
# backend parity: every policy, both cost models
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_POLICIES)
def test_jax_backend_matches_numpy_table1(trace, name):
    ref = run_policy(get_policy(name, **_kwargs(name)), trace)
    got = run_policy(get_policy(name, **_kwargs(name)), trace, backend="jax")
    assert got.policy == name
    assert got.n_windows == ref.n_windows
    assert np.array_equal(got.clique_sizes, ref.clique_sizes)
    assert_same_costs(ref.costs, got.costs)


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_jax_backend_matches_numpy_heterogeneous(sized_trace, het_env, name):
    kw = _kwargs(name, env=het_env, cost_model="heterogeneous")
    ref = run_policy(get_policy(name, **kw), sized_trace)
    got = run_policy(get_policy(name, **kw), sized_trace, backend="jax")
    assert_same_costs(ref.costs, got.costs)


def test_jax_backend_matches_numpy_tiered(sized_trace):
    kw = _kwargs("akpc", cost_model="tiered")
    ref = run_policy(get_policy("akpc", **kw), sized_trace)
    got = run_policy(get_policy("akpc", **kw), sized_trace, backend="jax")
    assert_same_costs(ref.costs, got.costs)


# ---------------------------------------------------------------------------
# the PR-2 chunking grid: batch sizes 1 / 7 / 4096 + ragged mixed session
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bs", [1, 7, 4096])
@pytest.mark.parametrize("model", ["table1", "heterogeneous"])
def test_jax_backend_chunking_grid(trace, sized_trace, het_env, bs, model):
    tr = trace if model == "table1" else sized_trace
    kw = _kwargs("akpc")
    if model == "heterogeneous":
        kw.update(env=het_env, cost_model=model)
    ref = run_policy(get_policy("akpc", **kw), tr, batch_size=bs)
    got = run_policy_jax(get_policy("akpc", **kw), tr, batch_size=bs)
    assert_same_costs(ref.costs, got.costs)


def test_jax_session_ragged_mixed_chunking(trace):
    """numpy feed -> jax feed_trace -> numpy feed == offline numpy."""
    ref = run_policy(get_policy("akpc", **_kwargs("akpc")), trace)
    s = CacheSession(get_policy("akpc", **_kwargs("akpc")), trace.n, trace.m)
    c1, c2 = 501, 2503              # ragged cuts that split T_CG windows
    s.feed(trace.items[:c1], trace.servers[:c1], trace.times[:c1])
    s.feed_trace(trace.slice(c1, c2), backend="jax")
    s.feed(trace.items[c2:], trace.servers[c2:], trace.times[c2:])
    assert_same_costs(ref.costs, s.costs)


def test_jax_session_snapshot_roundtrip(trace):
    ref = run_policy(get_policy("akpc", **_kwargs("akpc")), trace)
    s = CacheSession(get_policy("akpc", **_kwargs("akpc")), trace.n, trace.m,
                     backend="jax")
    cut = 2503
    s.feed_trace(trace.slice(0, cut))
    snap = s.snapshot()
    s2 = CacheSession(get_policy("akpc", **_kwargs("akpc")),
                      trace.n, trace.m).restore(snap)
    s2.feed(trace.items[cut:], trace.servers[cut:], trace.times[cut:])
    assert_same_costs(ref.costs, s2.costs)


# ---------------------------------------------------------------------------
# SweepEngine parity
# ---------------------------------------------------------------------------
def test_sweep_matches_serial_all_policies_table1(trace):
    pts = [SweepPoint(name, trace, _kwargs(name)) for name in ALL_POLICIES]
    eng = SweepEngine()
    res = eng.run(pts)
    for pt, got in zip(pts, res):
        ref = run_policy(get_policy(pt.policy, **pt.policy_kwargs), trace)
        assert got.policy == pt.policy
        assert got.n_windows == ref.n_windows
        assert got.costs.model == "table1"
        assert_same_costs(ref.costs, got.costs)


def test_sweep_matches_serial_all_policies_heterogeneous(sized_trace, het_env):
    pts = [
        SweepPoint(name, sized_trace,
                   _kwargs(name, env=het_env, cost_model="heterogeneous"))
        for name in ALL_POLICIES
    ]
    res = SweepEngine().run(pts)
    for pt, got in zip(pts, res):
        ref = run_policy(
            get_policy(pt.policy, **pt.policy_kwargs), sized_trace)
        assert got.costs.model == "heterogeneous"
        assert_same_costs(ref.costs, got.costs)


def test_sweep_shares_schedules_across_alpha_axis(trace):
    """An alpha sweep runs clique generation ONCE and still matches the
    per-point serial replays (alpha never enters the CGM)."""
    alphas = [0.6, 0.8, 1.0]
    pts = [
        SweepPoint("akpc", trace,
                   dict(params=CostParams(alpha=a), t_cg=T_CG,
                        top_frac=TOP_FRAC))
        for a in alphas
    ]
    eng = SweepEngine()
    res = eng.run(pts)
    assert eng.last_n_schedules == 1        # one schedule, three scenarios
    totals = set()
    for pt, got in zip(pts, res):
        ref = run_policy(get_policy(pt.policy, **pt.policy_kwargs), trace)
        assert_same_costs(ref.costs, got.costs)
        totals.add(round(got.total, 6))
    assert len(totals) == len(alphas)       # scenarios really differ


def test_sweep_does_not_share_across_cgm_axes(trace):
    """theta changes the CGM -> separate schedules, results still match."""
    pts = [
        SweepPoint("packcache", trace,
                   dict(params=CostParams(theta=th), t_cg=T_CG,
                        top_frac=TOP_FRAC))
        for th in (0.1, 0.3)
    ]
    eng = SweepEngine()
    res = eng.run(pts)
    assert eng.last_n_schedules == 2
    for pt, got in zip(pts, res):
        ref = run_policy(get_policy(pt.policy, **pt.policy_kwargs), trace)
        assert_same_costs(ref.costs, got.costs)


def test_sweep_numpy_backend_and_convenience(trace):
    grid = [dict(policy="no_packing", trace=trace,
                 policy_kwargs={"params": PARAMS})]
    a = sweep_points(grid, backend="numpy")[0]
    b = sweep_points(grid, backend="jax")[0]
    assert_same_costs(a.costs, b.costs)


def test_sweep_covers_registry():
    """The parity suites above must cover every registered policy (every
    registry name, aliases included, resolves to a covered policy)."""
    for name in list_policies():
        assert get_policy(name, params=PARAMS).name in ALL_POLICIES


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------
def test_unknown_backend_refused(trace):
    with pytest.raises(ValueError):
        run_policy(get_policy("no_packing", params=PARAMS), trace,
                   backend="tpu-magic")
    with pytest.raises(ValueError):
        SweepEngine(backend="tpu-magic")
    with pytest.raises(ValueError):
        CacheSession(get_policy("no_packing", params=PARAMS), trace.n,
                     trace.m, backend="tpu-magic")


def test_inexpressible_cost_model_refused(trace):
    """A custom registered CostModel has no jnp formula -> loud error."""

    class WeirdModel(CostModel):
        name = "weird_test_model"
        uses_sizes = False

        def dt(self):
            return np.full(self.env.m, self.params.dt)

        def transfer_cost_batch(self, counts, sizes, servers):
            return np.asarray(counts, float) ** 1.5

        def caching_rate(self, counts, sizes, servers):
            return np.asarray(counts, float)

    if "weird_test_model" not in __import__(
            "repro.core.cost", fromlist=["_COST_MODELS"])._COST_MODELS:
        register_cost_model("weird_test_model")(WeirdModel)
    pol = get_policy("no_packing", params=PARAMS,
                     cost_model="weird_test_model")
    with pytest.raises(NotImplementedError):
        run_policy(pol, trace, backend="jax")
    # the numpy backend still prices it fine
    run_policy(get_policy("no_packing", params=PARAMS,
                          cost_model="weird_test_model"), trace)


# ---------------------------------------------------------------------------
# trace-shard axis: shards/seeds as extra vmap lanes, costs merged
# ---------------------------------------------------------------------------
def test_sweep_shard_axis_matches_per_shard_serial():
    """A sharded point merges per-shard costs exactly and reports
    per-shard dispersion, lane-for-lane with the serial replays."""
    shards = [_trace(n_requests=1500, seed=s) for s in (3, 4, 5)]
    pts = [
        SweepPoint("akpc", shards,
                   dict(params=CostParams(alpha=a), t_cg=T_CG,
                        top_frac=TOP_FRAC))
        for a in (0.7, 0.9)
    ]
    eng = SweepEngine()
    res = eng.run(pts)
    # scenarios share the per-shard schedules: one build per shard
    assert eng.last_n_schedules == len(shards)
    for pt, got in zip(pts, res):
        subs = [run_policy(get_policy(pt.policy, **pt.policy_kwargs), tr)
                for tr in shards]
        merged = {f: sum(s.costs.as_dict()[f] for s in subs)
                  for f in INT_FIELDS + FLOAT_FIELDS}
        assert_same_costs(merged, got.costs)
        st = got.shard_stats
        assert st is not None and st["n"] == len(shards)
        np.testing.assert_allclose(
            st["totals"], [s.costs.total for s in subs], rtol=1e-9)
        np.testing.assert_allclose(
            st["mean"], np.mean(st["totals"]), rtol=1e-12)
        assert st["ci95"] >= 0.0


def test_sweep_shard_axis_numpy_backend_parity():
    """The numpy backend merges shards identically (same RunResult shape)."""
    shards = [_trace(n_requests=1200, seed=s) for s in (6, 7)]
    pt = SweepPoint("akpc", shards,
                    dict(params=PARAMS, t_cg=T_CG, top_frac=TOP_FRAC))
    got_j = SweepEngine(backend="jax").run([pt])[0]
    got_n = SweepEngine(backend="numpy").run([pt])[0]
    assert_same_costs(got_n.costs, got_j.costs)
    assert got_j.shard_stats["n"] == got_n.shard_stats["n"] == 2
    np.testing.assert_allclose(
        got_j.shard_stats["totals"], got_n.shard_stats["totals"], rtol=1e-9)
    # a plain (unsharded) point keeps shard_stats None
    plain = SweepEngine().run(
        [SweepPoint("akpc", shards[0],
                    dict(params=PARAMS, t_cg=T_CG, top_frac=TOP_FRAC))])[0]
    assert plain.shard_stats is None


def _stress_trace(profile, seed, n_requests=1200):
    return synth_trace(SynthConfig(
        kind="netflix", n_items=60, n_servers=12, n_requests=n_requests,
        t_max=30.0, bundle_cover=1.0, bundle_zipf=0.7, seed=seed,
        load_profile=profile,
        load_strength=4.0 if profile == "flash_crowd" else 0.8))


@pytest.mark.parametrize("profile", ["diurnal", "flash_crowd"])
def test_sweep_shard_axis_nonstationary_profiles(profile):
    """Non-stationary traces through the shard axis: merged totals equal
    the serial per-shard replays at 1e-9, and the shard-CI estimate
    tightens as seed-replica shards are added (1/sqrt(n) scaling holds to
    within the seed noise of these workloads)."""
    seeds = (3, 4, 5, 6, 7, 8)
    shards = [_stress_trace(profile, s) for s in seeds]
    kw = dict(params=PARAMS, t_cg=T_CG, top_frac=TOP_FRAC)
    got2, got6 = SweepEngine().run([
        SweepPoint("akpc", shards[:2], kw),
        SweepPoint("akpc", shards, kw),
    ])
    subs = [run_policy(get_policy("akpc", **kw), tr) for tr in shards]
    merged = {f: sum(s.costs.as_dict()[f] for s in subs)
              for f in INT_FIELDS + FLOAT_FIELDS}
    assert_same_costs(merged, got6.costs)
    np.testing.assert_allclose(
        got6.shard_stats["totals"], [s.costs.total for s in subs],
        rtol=1e-9)
    # non-stationarity really moved the per-shard costs apart
    assert got6.shard_stats["std"] > 0.0
    # CI width shrinks with the shard count (same seeds prefix both points)
    assert got6.shard_stats["ci95"] < got2.shard_stats["ci95"]


def test_sweep_shard_axis_rejects_mismatched_shards():
    a = _trace(n_requests=500, seed=1)
    b = synth_trace(SynthConfig(
        kind="netflix", n_items=61, n_servers=12, n_requests=500,
        t_max=30.0, bundle_cover=1.0, bundle_zipf=0.7, seed=2))
    with pytest.raises(ValueError, match="shards must share"):
        SweepEngine().run([SweepPoint(
            "akpc", [a, b], dict(params=PARAMS, t_cg=T_CG,
                                 top_frac=TOP_FRAC))])
