"""Kernel autowiring decision table + segment-reduction kernel parity."""
import numpy as np
import pytest

from repro.kernels.autowire import (
    default_cgm_hooks,
    default_segment_hooks,
    kernels_enabled,
)

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels.segment_reduce import (  # noqa: E402
    seg_running_argmax,
    seg_running_argmax_jnp,
    seg_running_argmax_ref,
    seg_running_max,
    seg_running_max_jnp,
    seg_running_max_ref,
)


# ---------------------------------------------------------------------------
# decision table: REPRO_KERNELS env override x backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("env,backend,expect", [
    # auto: engage on any live non-CPU accelerator, GPU included
    ("", "tpu", True),
    ("", "gpu", True),
    ("", "cuda", True),
    ("", "cpu", False),
    ("", None, False),              # jax missing/broken
    ("auto", "tpu", True),
    ("auto", "cpu", False),
    # force: engage everywhere (interpret mode on CPU)
    ("force", "cpu", True),
    ("on", None, True),
    ("1", "cpu", True),
    ("always", "gpu", True),
    # off: never engage
    ("off", "tpu", False),
    ("0", "gpu", False),
    ("never", "tpu", False),
    # case/whitespace robustness
    (" FORCE ", "cpu", True),
    ("OFF", "tpu", False),
])
def test_kernels_enabled_decision_table(env, backend, expect):
    assert kernels_enabled(backend, env=env) is expect


def test_env_variable_is_read(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "force")
    assert kernels_enabled("cpu") is True
    monkeypatch.setenv("REPRO_KERNELS", "off")
    assert kernels_enabled("tpu") is False
    monkeypatch.delenv("REPRO_KERNELS")
    assert kernels_enabled("cpu") is False


def test_default_hooks_follow_decision(monkeypatch):
    """On this CPU container, auto -> numpy/jnp oracles; force -> Pallas."""
    monkeypatch.setenv("REPRO_KERNELS", "auto")
    assert default_cgm_hooks() == (None, None)
    assert default_segment_hooks() == (None, None)
    monkeypatch.setenv("REPRO_KERNELS", "force")
    mm, pe = default_cgm_hooks()
    sm, sa = default_segment_hooks()
    assert callable(mm) and callable(pe)
    assert callable(sm) and callable(sa)


def test_forced_hooks_are_usable(monkeypatch):
    """Forced (interpret-mode) hooks must still compute correctly."""
    monkeypatch.setenv("REPRO_KERNELS", "force")
    sm, sa = default_segment_hooks()
    v = np.array([3.0, 1.0, 2.0, 5.0, 4.0], np.float32)
    s = np.array([1, 0, 0, 1, 0], bool)
    got = np.asarray(sm(jnp.asarray(v), jnp.asarray(s)))
    np.testing.assert_allclose(got, seg_running_max_ref(v, s))
    mv, mi = sa(jnp.asarray(v), jnp.asarray(s))
    rv, ri = seg_running_argmax_ref(v, s)
    np.testing.assert_allclose(np.asarray(mv), rv)
    assert np.array_equal(np.asarray(mi), ri)


# ---------------------------------------------------------------------------
# segment kernels: Pallas interpret mode == jnp fallback == numpy oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("L,p_start,seed", [
    (1, 1.0, 0), (2, 0.5, 1), (17, 0.3, 2), (64, 0.1, 3),
    (257, 0.05, 4), (1024, 0.02, 5),
])
def test_segment_running_max_parity(L, p_start, seed):
    rng = np.random.default_rng(seed)
    v = rng.normal(size=L)
    s = rng.random(L) < p_start
    s[0] = True
    want = seg_running_max_ref(v, s)
    got_jnp = np.asarray(seg_running_max_jnp(jnp.asarray(v), jnp.asarray(s)))
    got_pl = np.asarray(
        seg_running_max(jnp.asarray(v), jnp.asarray(s), interpret=True))
    np.testing.assert_allclose(got_jnp, want.astype(got_jnp.dtype), rtol=0)
    np.testing.assert_allclose(got_pl, want.astype(got_pl.dtype), rtol=0)


@pytest.mark.parametrize("L,p_start,seed", [
    (1, 1.0, 10), (31, 0.2, 11), (128, 0.05, 12), (1000, 0.01, 13),
])
def test_segment_running_argmax_parity(L, p_start, seed):
    rng = np.random.default_rng(seed)
    # duplicate values force the tie rule: LATEST index must win
    v = rng.integers(0, 5, L).astype(np.float64)
    s = rng.random(L) < p_start
    s[0] = True
    want_v, want_i = seg_running_argmax_ref(v, s)
    gv, gi = seg_running_argmax_jnp(jnp.asarray(v), jnp.asarray(s))
    pv, pi = seg_running_argmax(jnp.asarray(v), jnp.asarray(s),
                                interpret=True)
    np.testing.assert_allclose(np.asarray(gv), want_v)
    assert np.array_equal(np.asarray(gi), want_i)
    np.testing.assert_allclose(np.asarray(pv), want_v)
    assert np.array_equal(np.asarray(pi), want_i)


def test_segment_argmax_tie_breaks_latest():
    v = np.array([2.0, 2.0, 2.0, 1.0])
    s = np.array([True, False, False, False])
    _, idx = seg_running_argmax_jnp(jnp.asarray(v), jnp.asarray(s))
    assert np.asarray(idx).tolist() == [0, 1, 2, 2]
