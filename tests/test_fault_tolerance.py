"""Crash-recovery: identical final state with and without failures."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import PackedDataPipeline, ShardStore, TokenBatcher
from repro.distributed import FailureInjector, StragglerPolicy, TrainController
from repro.launch.train import make_train_step
from repro.models.api import build_model
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=128, tie_embeddings=True)


def _controller(tmp, steps_at=()):
    model = build_model(CFG)
    store = ShardStore(n_shards=16, shard_tokens=256, vocab=128, n_domains=4)
    pipe = PackedDataPipeline(store, batch_rows=4, seq_len=32)
    batcher = TokenBatcher(pipe, accum=2, microbatch=2)
    ts = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=2,
                                                    total_steps=50)))

    def init_state():
        p = model.init(jax.random.PRNGKey(0))
        return p, adamw_init(p)

    return TrainController(ts, init_state, batcher, str(tmp), ckpt_every=4,
                           injector=FailureInjector(at_steps=steps_at))


def test_recovery_bitwise_identical(tmp_path):
    p1, _ = _controller(tmp_path / "a").run(total_steps=12)
    ctl = _controller(tmp_path / "b", steps_at=(6,))
    p2, _ = ctl.run(total_steps=12)
    assert ctl.restarts == 1
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_straggler_policies():
    for mode in ("wait", "skip", "backup"):
        sp = StragglerPolicy(mode=mode, p_straggle=0.3, seed=1)
        times = [sp.step_time(s) for s in range(50)]
        assert all(t > 0 for t in times)
    wait = StragglerPolicy(mode="wait", p_straggle=0.3, seed=1)
    backup = StragglerPolicy(mode="backup", p_straggle=0.3, seed=1)
    t_wait = sum(wait.step_time(s) for s in range(100))
    t_backup = sum(backup.step_time(s) for s in range(100))
    assert t_backup < t_wait               # mitigation pays off
