"""Fig. 5 orderings + OPT lower-bound validity."""
import pytest

from repro.core import (
    AKPCConfig,
    CostParams,
    opt_lower_bound,
    run_akpc,
    run_dp_greedy,
    run_no_packing,
    run_packcache2,
)
from repro.traces import paper_trace


@pytest.fixture(scope="module")
def results():
    params = CostParams()
    tr = paper_trace("netflix", n_requests=30000, seed=0)
    t_cg = 0.3
    return {
        "akpc": run_akpc(tr, AKPCConfig(params=params, t_cg=t_cg,
                                        top_frac=1.0)).costs,
        "nopack": run_no_packing(tr, params),
        "pc2": run_packcache2(tr, params, t_cg=t_cg, top_frac=1.0),
        "dpg": run_dp_greedy(tr, params, top_frac=1.0),
        "opt": opt_lower_bound(tr, params),
    }


def test_opt_is_lower_bound(results):
    opt = results["opt"].total
    for k in ("akpc", "nopack", "pc2", "dpg"):
        assert results[k].total >= opt


def test_akpc_beats_online_baselines(results):
    assert results["akpc"].total < results["pc2"].total
    assert results["akpc"].total < results["nopack"].total


def test_packing_beats_no_packing(results):
    assert results["pc2"].total < results["nopack"].total


# ---------------------------------------------------------------------------
# TTL keep-or-not baseline (PR 7; Le Scouarnec et al., arXiv 1312.0499)
# ---------------------------------------------------------------------------
def test_ttl_keep_or_not_semantics():
    """Hot items stay cached (hits), items voted nokeep are forced
    misses: every access to them prices as a plain transfer."""
    import numpy as np

    from repro.core import get_policy, run_policy
    from repro.traces.loader import Trace

    params = CostParams()
    t_cg = 4.0
    # item 0: dense re-access well inside the TTL (kept after window 1);
    # item 1: one lonely request per window (voted nokeep)
    times, items = [], []
    t = 0.0
    while t < 20.0:
        times += [t, t + 0.05]
        items += [0, 1 if int(t) % 4 == 0 else 0]
        t += 0.1
    order = np.argsort(times, kind="stable")
    tr = Trace(times=np.asarray(times, np.float64)[order],
               servers=np.zeros(len(times), np.int32),
               items=np.asarray(items, np.int32)[order].reshape(-1, 1),
               n=2, m=1, name="ttl-unit")
    res = run_policy(get_policy("ttl", params=params, t_cg=t_cg), tr)
    nopack = run_no_packing(tr, params)
    assert res.costs.n_hits > 0                      # item 0 stays resident
    # nokeep items never pay caching rent, so TTL undercuts always-cache
    assert res.costs.total <= nopack.total
    # no packing ever happens: the partition is all singletons
    assert (res.clique_sizes == 1).all()

    # the keep vote survives a snapshot (policy state_dict carries it)
    keep = get_policy("ttl", params=params, t_cg=t_cg)
    run_policy(keep, tr)
    state = keep.state_dict()
    fresh = get_policy("ttl", params=params, t_cg=t_cg)
    fresh.load_state_dict(state)
    assert np.array_equal(fresh.item_keep(), keep.item_keep())
