"""Fig. 5 orderings + OPT lower-bound validity."""
import pytest

from repro.core import (
    AKPCConfig,
    CostParams,
    opt_lower_bound,
    run_akpc,
    run_dp_greedy,
    run_no_packing,
    run_packcache2,
)
from repro.traces import paper_trace


@pytest.fixture(scope="module")
def results():
    params = CostParams()
    tr = paper_trace("netflix", n_requests=30000, seed=0)
    t_cg = 0.3
    return {
        "akpc": run_akpc(tr, AKPCConfig(params=params, t_cg=t_cg,
                                        top_frac=1.0)).costs,
        "nopack": run_no_packing(tr, params),
        "pc2": run_packcache2(tr, params, t_cg=t_cg, top_frac=1.0),
        "dpg": run_dp_greedy(tr, params, top_frac=1.0),
        "opt": opt_lower_bound(tr, params),
    }


def test_opt_is_lower_bound(results):
    opt = results["opt"].total
    for k in ("akpc", "nopack", "pc2", "dpg"):
        assert results[k].total >= opt


def test_akpc_beats_online_baselines(results):
    assert results["akpc"].total < results["pc2"].total
    assert results["akpc"].total < results["nopack"].total


def test_packing_beats_no_packing(results):
    assert results["pc2"].total < results["nopack"].total
