"""StateLayout (ISSUE 8): bucketed compilation + row-sharded device state.

Contracts under test:

* geometry — dense is the bitwise default (``layout=None`` everywhere);
  bucketed rounds (n, m) up to padding buckets with the dump row LAST;
  row_sharded pads rows to a shard multiple; ``is_dense_for`` gates the
  dense-only device-CGM path (``init_cgm_carry`` refuses otherwise);
* parity — every layout replays the SAME costs as the numpy engine at
  1e-9 (integers exact), including the n=1 edge and an n=10^4 catalog;
* cohort compilation — a mixed-(n, m) SweepEngine grid under a bucketed
  layout compiles once per bucket cohort, NOT once per point;
* round-trips — snapshots port freely dense<->bucketed (host state is
  dense (k, m) under every layout); a row-sharded snapshot restored
  into a row-sharded session refuses a mismatched shard count;
* pad_schedule — padding preserves the schedule's state geometry and
  the dump-row sentinel under every layout;
* mesh placement — on >= 4 devices (the CI multi-device lane sets
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``), a
  row-sharded layout demonstrably spreads the state rows across the
  ``state_row`` mesh axis and still prices at 1e-9.
"""
import numpy as np
import pytest

from repro.core import CostParams, get_policy, run_policy
from repro.core import engine_jax as ej
from repro.core.engine_jax import run_policy_jax
from repro.core.session import CacheSession
from repro.core.state_layout import DENSE, StateLayout
from repro.core.sweep import SweepEngine, SweepPoint
from repro.traces import SynthConfig, synth_trace

jax = pytest.importorskip("jax")

PARAMS = CostParams()
INT_FIELDS = ("n_requests", "n_item_requests", "n_misses", "n_hits",
              "items_transferred")
FLOAT_FIELDS = ("transfer", "caching", "keepalive_rent", "total")

BUCKETED = StateLayout(kind="bucketed", row_bucket=16, col_bucket=8)
SHARDED3 = StateLayout(kind="row_sharded", shards=3)


def _trace(n_items=40, n_servers=10, n_requests=2500, seed=5, **kw):
    kw.setdefault("bundle_cover", 1.0)
    kw.setdefault("bundle_zipf", 0.7)
    return synth_trace(SynthConfig(
        kind="netflix", n_items=n_items, n_servers=n_servers,
        n_requests=n_requests, t_max=20.0, seed=seed, **kw))


def _policy(name="akpc", **kw):
    if name in ("akpc", "ttl", "packcache"):
        kw.setdefault("t_cg", 0.9)
    if name in ("akpc", "packcache"):
        kw.setdefault("top_frac", 1.0)
    return get_policy(name, params=PARAMS, **kw)


def assert_same_costs(ref, got):
    a, b = ref.as_dict(), got.as_dict()
    for f in INT_FIELDS:
        assert a[f] == b[f], f"{f}: {a[f]} != {b[f]}"
    for f in FLOAT_FIELDS:
        assert np.isclose(a[f], b[f], rtol=1e-9, atol=1e-9), \
            f"{f}: {a[f]} != {b[f]}"


# ---------------------------------------------------------------------------
# geometry
# ---------------------------------------------------------------------------
def test_dense_is_the_default():
    assert StateLayout.resolve(None) is DENSE
    assert DENSE.state_dims(60, 600) == (61, 600)
    assert DENSE.dump_row(60) == 60
    assert DENSE.is_dense_for(60, 600)
    assert DENSE.row_shards == 1


def test_bucketed_geometry_rounds_up():
    lay = StateLayout(kind="bucketed", row_bucket=64, col_bucket=32)
    assert lay.state_dims(50, 20) == (65, 32)
    assert lay.state_dims(64, 32) == (65, 32)
    assert lay.state_dims(65, 33) == (129, 64)
    assert lay.dump_row(50) == 64          # always the LAST row
    assert lay.state_dims(1, 1) == (65, 32)       # n=1 edge
    rows, cols = lay.state_dims(10_000, 600)
    assert rows == 10_048 + 1 and (rows - 1) % 64 == 0 and cols == 608
    assert not lay.is_dense_for(50, 20)
    assert lay.is_dense_for(64, 32)        # buckets land exactly on dims


def test_row_sharded_geometry_and_str_resolve():
    lay = StateLayout(kind="row_sharded", shards=4)
    assert lay.row_shards == 4
    assert lay.state_rows(60) % 4 == 0
    assert not lay.is_dense_for(60, 10)
    assert StateLayout(kind="row_sharded", shards=1).is_dense_for(60, 10)
    with pytest.raises(ValueError):
        StateLayout.resolve("row_sharded")      # needs a mesh or shards
    assert StateLayout.resolve("bucketed").kind == "bucketed"


def test_state_bytes_telemetry():
    assert DENSE.state_bytes(60, 600) == 61 * 600 * 8 + 61 * 4
    sh = StateLayout(kind="row_sharded", shards=4)
    assert sh.state_bytes_per_device(9999, 600) * 4 == sh.state_bytes(
        9999, 600)


def test_device_cgm_layout_gating():
    """The compact CGM carry is dense-n regardless of layout, so any
    row-unsharded layout qualifies (bucketed included); row-sharded
    state is refused — the in-scan segment reductions need every slot
    on one device."""
    from repro.core import cgm_jax
    from repro.core.engine import CacheState, CliquePartition

    st = CacheState.fresh(CliquePartition.singletons(8), 4)
    carry = cgm_jax.init_cgm_carry(st, None, None, n=8, m=4,
                                   uses_sizes=False, item_sizes=None,
                                   layout=BUCKETED, h=4, wcap=64)
    assert carry["of"].shape == (8,)                # dense-n carry
    with pytest.raises(ValueError):
        cgm_jax.init_cgm_carry(st, None, None, n=8, m=4,
                               uses_sizes=False, item_sizes=None,
                               layout=SHARDED3, h=4, wcap=64)


# ---------------------------------------------------------------------------
# replay parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", [None, BUCKETED, SHARDED3],
                         ids=["dense", "bucketed", "row_sharded"])
@pytest.mark.parametrize("policy", ["akpc", "no_packing", "ttl"])
def test_replay_parity_all_layouts(layout, policy):
    trace = _trace()
    ref = run_policy(_policy(policy), trace)
    got = run_policy_jax(_policy(policy), trace, layout=layout)
    assert_same_costs(ref.costs, got.costs)


def test_replay_parity_n_equals_1():
    # single-item catalog (the bundle generator needs n >= bundle size,
    # so build the trace by hand): one item pinging 3 servers
    from repro.traces.loader import Trace

    rng = np.random.default_rng(0)
    R = 400
    trace = Trace(
        times=np.sort(rng.uniform(0.0, 20.0, R)),
        servers=rng.integers(0, 3, R).astype(np.int32),
        items=np.zeros((R, 1), np.int32),
        n=1, m=3, name="one-item")
    ref = run_policy(_policy("no_packing"), trace)
    got = run_policy_jax(_policy("no_packing"), trace, layout=BUCKETED)
    assert_same_costs(ref.costs, got.costs)


@pytest.mark.parametrize("layout", [
    StateLayout(kind="bucketed"),           # default 1024-row buckets
    StateLayout(kind="row_sharded", shards=4),
], ids=["bucketed", "row_sharded"])
def test_replay_parity_large_catalog(layout):
    """The ISSUE-8 catalog-scale gate: n=10^4 items replays on the JAX
    backend with 1e-9 cost parity vs the numpy engine."""
    trace = _trace(n_items=10_000, n_servers=24, n_requests=4000, seed=1,
                   server_affinity=2)
    ref = run_policy(_policy("no_packing"), trace)
    got = run_policy_jax(_policy("no_packing"), trace, layout=layout)
    assert_same_costs(ref.costs, got.costs)


# ---------------------------------------------------------------------------
# bucket cohorts: compile per cohort, not per point
# ---------------------------------------------------------------------------
def test_mixed_shape_sweep_compiles_per_cohort():
    lay = StateLayout(kind="bucketed", row_bucket=64, col_bucket=16)
    shapes = [(30, 8), (40, 10), (90, 20), (100, 24)]
    pts = [SweepPoint("akpc", _trace(n_items=n, n_servers=m, seed=s),
                      dict(params=PARAMS, t_cg=0.9, top_frac=1.0),
                      tag=f"{n}x{m}")
           for s, (n, m) in enumerate(shapes)]
    cohorts = {lay.state_dims(n, m) for n, m in shapes}
    assert len(cohorts) == 2               # the grid must be ragged
    before = ej.SCAN_TRACES
    got = SweepEngine(backend="jax", layout=lay).run(pts)
    assert ej.SCAN_TRACES - before <= len(cohorts)
    for pt, g in zip(pts, got):
        ref = run_policy(get_policy(pt.policy, **pt.policy_kwargs),
                         pt.trace)
        assert_same_costs(ref.costs, g.costs)


def test_pad_schedule_preserves_state_geometry():
    trace = _trace()
    pol = _policy("akpc")
    pol.bind(trace.n, trace.m)
    from repro.core import CacheEnvironment, get_cost_model
    from repro.core.engine import CliquePartition

    env = CacheEnvironment.resolve(None, trace, PARAMS)
    s = ej.build_schedule(
        CliquePartition.singletons(trace.n), trace, pol.on_window,
        pol.t_cg, model=get_cost_model("table1", env), env=env,
        layout=BUCKETED)
    assert (s.state_rows, s.state_cols) == BUCKETED.state_dims(
        trace.n, trace.m)
    dims = {k: v + 7 for k, v in ej.schedule_dims(s).items()}
    padded = ej.pad_schedule(s, dims)
    assert (padded.state_rows, padded.state_cols) == (
        s.state_rows, s.state_cols)
    # padded event slots scatter into the dump row — the LAST state row
    K = s.state_rows - 1
    assert int(padded.xs["ev_c"].max()) <= K
    assert int(padded.xs["ev_c"][-1, -1]) == K


# ---------------------------------------------------------------------------
# snapshot round-trips
# ---------------------------------------------------------------------------
def _feed(sess, trace, lo, hi):
    sess.feed(trace.items[lo:hi], trace.servers[lo:hi],
              trace.times[lo:hi])


def test_snapshot_round_trip_dense_bucketed():
    trace = _trace()
    ref = CacheSession(_policy(), trace.n, trace.m)
    ref.feed_trace(trace)

    half = trace.n_requests // 2
    a = CacheSession(_policy(), trace.n, trace.m)          # dense
    _feed(a, trace, 0, half)
    b = CacheSession(_policy(), trace.n, trace.m, layout=BUCKETED)
    b.restore(a.snapshot())
    _feed(b, trace, half, trace.n_requests)
    assert_same_costs(ref.costs, b.costs)

    # and back: bucketed snapshot -> dense session
    c = CacheSession(_policy(), trace.n, trace.m, layout=BUCKETED)
    _feed(c, trace, 0, half)
    d = CacheSession(_policy(), trace.n, trace.m)
    d.restore(c.snapshot())
    _feed(d, trace, half, trace.n_requests)
    assert_same_costs(ref.costs, d.costs)


def test_snapshot_sharded_refuses_mismatched_shards():
    trace = _trace()
    a = CacheSession(_policy(), trace.n, trace.m,
                     layout=StateLayout(kind="row_sharded", shards=2))
    snap = a.snapshot()
    b = CacheSession(_policy(), trace.n, trace.m,
                     layout=StateLayout(kind="row_sharded", shards=4))
    with pytest.raises(ValueError, match="shard"):
        b.restore(snap)
    # dense and bucketed sessions accept the same snapshot freely
    CacheSession(_policy(), trace.n, trace.m).restore(snap)
    CacheSession(_policy(), trace.n, trace.m,
                 layout=BUCKETED).restore(snap)


# ---------------------------------------------------------------------------
# mesh placement (the CI multi-device lane)
# ---------------------------------------------------------------------------
needs_4_devices = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")


@needs_4_devices
def test_make_sweep_mesh_state_row_axis():
    from repro.launch.mesh import make_sweep_mesh

    mesh = make_sweep_mesh(state_rows=2)
    assert mesh.axis_names == ("scenario", "state_row")
    assert mesh.shape["state_row"] == 2
    with pytest.raises(ValueError):
        make_sweep_mesh(n_devices=4, state_rows=3)


@needs_4_devices
def test_row_sharded_state_spans_devices():
    from jax.experimental import enable_x64

    from repro.launch.mesh import make_sweep_mesh

    mesh = make_sweep_mesh(n_devices=4, state_rows=4)
    lay = StateLayout(kind="row_sharded", mesh=mesh)
    assert lay.row_shards == 4
    E0, a0 = ej.fresh_state_arrays(63, 10, lay)
    with enable_x64():
        Ed, ad = lay.place_state(E0, a0)
    assert len(Ed.sharding.device_set) == 4
    assert len(ad.sharding.device_set) == 4


@needs_4_devices
def test_row_sharded_parity_on_mesh():
    """The acceptance gate: the row-sharded layout passes parity on a
    4-virtual-device CPU mesh (state rows spread over ``state_row``)."""
    from repro.launch.mesh import make_sweep_mesh

    mesh = make_sweep_mesh(n_devices=4, state_rows=4)
    lay = StateLayout(kind="row_sharded", mesh=mesh)
    trace = _trace()
    for policy in ("akpc", "no_packing"):
        ref = run_policy(_policy(policy), trace)
        got = run_policy_jax(_policy(policy), trace, layout=lay)
        assert_same_costs(ref.costs, got.costs)


@needs_4_devices
def test_sweep_engine_mesh_row_sharded():
    from repro.launch.mesh import make_sweep_mesh

    mesh = make_sweep_mesh(n_devices=4, state_rows=2)
    lay = StateLayout(kind="row_sharded", mesh=mesh)
    pts = [SweepPoint("akpc", _trace(seed=s),
                      dict(params=PARAMS, t_cg=0.9, top_frac=1.0))
           for s in range(2)]
    got = SweepEngine(backend="jax", mesh=mesh, layout=lay).run(pts)
    for pt, g in zip(pts, got):
        ref = run_policy(get_policy(pt.policy, **pt.policy_kwargs),
                         pt.trace)
        assert_same_costs(ref.costs, g.costs)
