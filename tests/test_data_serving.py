"""Data pipeline determinism/resume + AKPC expert-cache integration."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import PackedDataPipeline, ShardStore
from repro.serving import BatchedServer, ExpertCacheManager, Request
from repro.configs import get_smoke_config
from repro.models.api import build_model


def test_pipeline_deterministic_and_resumable():
    store = ShardStore(n_shards=32, shard_tokens=256, vocab=100, n_domains=4)
    p1 = PackedDataPipeline(store, batch_rows=4, seq_len=32, seed=5)
    seq = [next(p1) for _ in range(6)]
    p2 = PackedDataPipeline(store, batch_rows=4, seq_len=32, seed=5)
    for _ in range(3):
        next(p2)
    p3 = PackedDataPipeline(store, batch_rows=4, seq_len=32, seed=5)
    p3.load_state_dict({"step": 3})
    for i in range(3):
        np.testing.assert_array_equal(next(p2), seq[3 + i])
        b3 = next(p3)
        np.testing.assert_array_equal(b3, seq[3 + i])


def test_expert_cache_savings():
    """Co-activated experts -> cliques -> AKPC beats per-expert fetching."""
    rng = np.random.default_rng(0)
    mgr = ExpertCacheManager(n_experts=32, n_hosts=4, t_cg=16.0)
    groups = [np.arange(8 * g, 8 * g + 8) for g in range(4)]   # co-activation
    for step in range(400):
        g = groups[int(rng.integers(0, 4) if rng.random() < 0.3 else 0)]
        topk = rng.choice(g, size=(4, 2))
        mgr.observe(topk, host=int(rng.integers(0, 4)))
    stats = mgr.stats()
    assert stats.akpc_total < stats.nopack_total
    assert len(stats.cliques) > 0


def test_expert_cache_snapshot_restore_failover():
    """A standby manager restored from a snapshot keeps observing (the
    manager clock/history must travel with the session state)."""
    rng = np.random.default_rng(7)
    mk = lambda: ExpertCacheManager(n_experts=16, n_hosts=2, t_cg=8.0)
    obs = [(rng.choice(8, size=(3, 2)), int(rng.integers(0, 2)))
           for _ in range(120)]

    primary = mk()
    for topk, host in obs[:60]:
        primary.observe(topk, host=host)
    standby = mk()
    standby.restore(primary.snapshot())
    for mgr in (primary, standby):
        for topk, host in obs[60:]:
            mgr.observe(topk, host=host)
    assert standby.session.costs.as_dict() == primary.session.costs.as_dict()
    assert standby.cliques() == primary.cliques()
    assert standby.stats().nopack_total == primary.stats().nopack_total


def test_packed_tables_layout():
    mgr = ExpertCacheManager(n_experts=8, n_hosts=1, t_cg=4.0)
    rng = np.random.default_rng(1)
    for step in range(40):
        mgr.observe(rng.choice(np.arange(4), size=(2, 2)), host=0)
    w = rng.normal(size=(8, 6)).astype(np.float32)
    table, where = mgr.packed_tables(w)
    for e in range(8):
        ci, slot = where[e]
        np.testing.assert_array_equal(table[ci, slot], w[e])


def test_batched_server_generates():
    cfg = get_smoke_config("qwen2_5_3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = BatchedServer(model, params, batch_size=2, cache_len=64)
    for i in range(3):
        srv.submit(Request(rid=i, prompt=[1 + i, 2, 3], max_new=4))
    done = srv.run(max_steps=200)
    assert len(done) == 3
    assert all(len(r.out) == 4 or r.out[-1] == srv.eos for r in done)


# ---------------------------------------------------------------------------
# backend="live" routing (PR 7): device-resident session behind the same API
# ---------------------------------------------------------------------------
def test_expert_cache_live_backend_matches_session():
    def run(backend):
        rng = np.random.default_rng(7)
        mgr = ExpertCacheManager(n_experts=16, n_hosts=4, t_cg=8.0,
                                 backend=backend)
        for _ in range(120):
            mgr.observe(rng.integers(0, 16, size=(32, 2)),
                        host=int(rng.integers(0, 4)))
        return mgr

    a, b = run("session"), run("live")
    sa, sb = a.stats(), b.stats()          # stats() drains the live engine
    assert np.isclose(sa.akpc_total, sb.akpc_total, rtol=1e-9)
    assert sa.nopack_total == sb.nopack_total
    assert sa.cliques == sb.cliques

    # checkpoints cross the backend boundary: live -> session
    standby = ExpertCacheManager(n_experts=16, n_hosts=4, t_cg=8.0)
    standby.restore(b.snapshot())
    rng = np.random.default_rng(11)
    obs = [(rng.integers(0, 16, size=(32, 2)), int(rng.integers(0, 4)))
           for _ in range(60)]
    for mgr in (b, standby):
        for topk, host in obs:
            mgr.observe(topk, host=host)
    assert np.isclose(b.stats().akpc_total, standby.stats().akpc_total,
                      rtol=1e-9)


def test_pipeline_live_backend_matches_session():
    def run(backend):
        store = ShardStore(n_shards=64, shard_tokens=256, vocab=100,
                           n_domains=8, seed=0)
        p = PackedDataPipeline(store, batch_rows=8, seq_len=32, t_cg=16.0,
                               backend=backend)
        return p, [next(p) for _ in range(40)]

    p1, o1 = run("session")
    p2, o2 = run("live")
    for x, y in zip(o1, o2):               # token stream is backend-blind
        np.testing.assert_array_equal(x, y)
    p2.cache.drain()
    assert np.isclose(p1.cache.costs.total, p2.cache.costs.total, rtol=1e-9)


def test_unknown_backend_refused():
    import pytest

    with pytest.raises(ValueError):
        ExpertCacheManager(8, 2, backend="bogus")
    with pytest.raises(ValueError):
        PackedDataPipeline(ShardStore(8), batch_rows=2, seq_len=8,
                           backend="bogus")
