"""Device-resident clique generation (PR 6 tentpole; DESIGN.md §11).

Contracts under test:

* oracle parity — the on-device CGM (window CRM -> adjust -> split ->
  approximate merge, inside the jit'd scan) produces partitions
  element-for-element identical to the frozen ``cliques_ref`` oracle at
  EVERY chained T_CG boundary, across a fig7-style theta x gamma x omega
  grid run as ONE vmapped device call;
* zero host CGM calls — a device replay / fig7 sweep never calls the
  host ``generate_cliques`` (the ``cliques.CGM_CALLS`` counter stays
  flat) and a CGM-axis sweep shares ONE schedule;
* gating — ``wants_device_cgm`` refuses non-AKPC policies, custom CRM
  hooks and oversized catalogs; ``REPRO_JAX_CGM=off`` forces the host
  path and still reproduces the numpy engine;
* kernels — the ``merge_step.merge_density`` Pallas kernel is
  bit-identical to the jnp fallback in interpret mode.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import (
    CacheEnvironment,
    CostParams,
    SweepEngine,
    SweepPoint,
    get_policy,
    run_policy,
)
from repro.core import cliques as cliques_mod
from repro.core import cliques_ref as oracle
from repro.core import cgm_jax
from repro.core.crm import build_window_crm
from repro.core.engine_jax import JaxReplayEngine, run_policy_jax
from repro.traces import SynthConfig, synth_trace

N_ITEMS = 48
T_CG = 0.73
TOP_FRAC = 0.5

THETAS = (0.1, 0.3)
GAMMAS = (0.6, 0.95)
OMEGAS = (3, 5)


def _trace(n_requests=900, seed=5, m=6):
    return synth_trace(SynthConfig(
        kind="netflix", n_items=N_ITEMS, n_servers=m,
        n_requests=n_requests, t_max=9.0, bundle_cover=1.0,
        bundle_zipf=0.7, seed=seed))


def _kw(theta, gamma, omega, **extra):
    kw = dict(params=CostParams(theta=theta, gamma=gamma, omega=omega),
              t_cg=T_CG, top_frac=TOP_FRAC)
    kw.update(extra)
    return kw


def _oracle_trajectory(trace, theta, gamma, omega, *, enable_split=True,
                       enable_acm=True, t_cg=T_CG):
    """The frozen-oracle partition at every T_CG boundary, walking the
    trace exactly as ``ReplayEngine.replay`` / ``build_cgm_schedule`` do."""
    times = trace.times
    R = times.shape[0]
    next_cg = float(times[0]) + t_cg
    win_start = pos = 0
    prev = prev_crm = None
    parts = []
    while pos < R:
        cut = int(np.searchsorted(times, next_cg, side="left"))
        if cut <= pos:
            t = float(times[pos])
            crm = build_window_crm(
                trace.items[win_start:pos], trace.n, theta,
                top_frac=TOP_FRAC)
            prev = oracle.generate_cliques(
                prev, prev_crm, crm, trace.n, omega, gamma,
                enable_split=enable_split, enable_approx_merge=enable_acm)
            parts.append(prev.clique_of.copy())
            prev_crm = crm
            win_start = pos
            while next_cg <= t:
                next_cg += t_cg
            continue
        pos = cut
    return parts


@pytest.fixture(scope="module")
def trace():
    return _trace()


def test_device_partitions_match_oracle_fig7_grid(trace):
    """One vmapped device call over the theta x gamma x omega grid; every
    lane's partition at every chained boundary == the cliques_ref oracle,
    element for element."""
    combos = [(th, g, om) for th in THETAS for g in GAMMAS for om in OMEGAS]
    pol0 = get_policy("akpc", **_kw(*combos[0]))
    pol0.bind(trace.n, trace.m)
    env = CacheEnvironment.resolve(None, trace, pol0.params)
    jeng = JaxReplayEngine(trace.n, trace.m, pol0.params, env=env)
    sched = cgm_jax.build_cgm_schedule(trace, T_CG, uses_sizes=False)
    assert sched.boundary_steps.size >= 3          # chained windows
    cspecs = []
    for th, g, om in combos:
        p = get_policy("akpc", **_kw(th, g, om))
        p.bind(trace.n, trace.m)
        cspecs.append(cgm_jax.cgm_spec(p.config, p.config.params, trace.n))
    cspec = {k: np.stack([np.asarray(c[k]) for c in cspecs])
             for k in cspecs[0]}
    S = len(combos)
    carry1 = cgm_jax.init_cgm_carry(
        jeng.engine.state, None, None, n=trace.n, m=trace.m,
        uses_sizes=False, item_sizes=None, schedule=sched)
    carry0 = {k: np.stack([v] * S) for k, v in carry1.items()}
    spec = {k: np.stack([v] * S) for k, v in jeng._spec.items()}
    final, ofs = cgm_jax.run_cgm_schedule(
        sched, spec, jeng._statics, cspec, carry0, None)
    for lane, (th, g, om) in enumerate(combos):
        want = _oracle_trajectory(trace, th, g, om)
        assert len(want) == sched.boundary_steps.size
        for w, (b, ref_of) in enumerate(zip(sched.boundary_steps, want)):
            got = ofs[lane, int(b)]
            assert np.array_equal(got, ref_of), \
                f"theta={th} gamma={g} omega={om} window={w}"
        assert np.array_equal(final["of"][lane], want[-1])


@pytest.mark.parametrize("name", ["akpc", "akpc_no_acm", "akpc_base"])
def test_device_ablation_variants_match_oracle(trace, name):
    """Split/merge ablations flow through the same static gates."""
    pol = get_policy(name, **_kw(0.2, 0.85, 4))
    cfg = pol.config
    res = run_policy_jax(pol, trace)
    want = _oracle_trajectory(
        trace, 0.2, 0.85, 4 if cfg.enable_split else trace.n,
        enable_split=cfg.enable_split,
        enable_acm=cfg.enable_approx_merge)
    # run_policy_jax syncs the policy's final partition from the device
    assert np.array_equal(
        res.clique_sizes, np.bincount(want[-1]).astype(np.int64))


def test_fig7_sweep_zero_host_cgm_calls(trace):
    """The acceptance bar: a fig7 sweep shares ONE schedule and performs
    ZERO host clique-generation calls — and still matches the numpy
    engine cost-for-cost."""
    pts = [SweepPoint("akpc", trace, _kw(th, g, om))
           for th in THETAS for g in GAMMAS for om in OMEGAS]
    eng = SweepEngine()
    before = cliques_mod.CGM_CALLS
    res = eng.run(pts)
    assert cliques_mod.CGM_CALLS == before          # zero host CGM calls
    assert eng.last_n_schedules == 1                # one shared schedule
    for pt, got in zip(pts[:2], res[:2]):           # spot-check cost parity
        ref = run_policy(get_policy(pt.policy, **pt.policy_kwargs), trace)
        assert got.n_windows == ref.n_windows
        assert np.array_equal(got.clique_sizes, ref.clique_sizes)
        for f in ("transfer", "caching", "keepalive_rent", "total"):
            assert np.isclose(ref.costs.as_dict()[f], got.costs.as_dict()[f],
                              rtol=1e-9, atol=1e-9), f


def test_replay_routes_device_and_counter_flat(trace):
    before = cliques_mod.CGM_CALLS
    got = run_policy_jax(get_policy("akpc", **_kw(0.2, 0.85, 4)), trace)
    assert cliques_mod.CGM_CALLS == before
    ref = run_policy(get_policy("akpc", **_kw(0.2, 0.85, 4)), trace)
    assert np.array_equal(got.clique_sizes, ref.clique_sizes)
    assert got.costs.n_misses == ref.costs.n_misses


def test_escape_hatch_forces_host_path(trace, monkeypatch):
    monkeypatch.setenv("REPRO_JAX_CGM", "off")
    pol = get_policy("akpc", **_kw(0.2, 0.85, 4))
    pol.bind(trace.n, trace.m)
    env = CacheEnvironment.resolve(None, trace, pol.params)
    from repro.core.cost import get_cost_model

    model = get_cost_model("table1", env)
    assert not cgm_jax.wants_device_cgm(pol, trace, model)
    before = cliques_mod.CGM_CALLS
    got = run_policy_jax(get_policy("akpc", **_kw(0.2, 0.85, 4)), trace)
    assert cliques_mod.CGM_CALLS > before           # host CGM ran
    ref = run_policy(get_policy("akpc", **_kw(0.2, 0.85, 4)), trace)
    assert np.isclose(got.costs.total, ref.costs.total, rtol=1e-9)


def test_wants_device_cgm_gating(trace, monkeypatch):
    pol = get_policy("akpc", **_kw(0.2, 0.85, 4))
    pol.bind(trace.n, trace.m)
    env = CacheEnvironment.resolve(None, trace, pol.params)
    from repro.core.cost import get_cost_model

    model = get_cost_model("table1", env)
    assert cgm_jax.wants_device_cgm(pol, trace, model)
    # non-AKPC configs are refused (packcache has its own window logic)
    pc = get_policy("packcache", params=CostParams(), t_cg=T_CG,
                    top_frac=TOP_FRAC)
    pc.bind(trace.n, trace.m)
    assert not cgm_jax.wants_device_cgm(pc, trace, model)
    # custom CRM hooks mean the host hooks must run
    hooked = get_policy("akpc", **_kw(0.2, 0.85, 4,
                                      crm_matmul=lambda H: H.T @ H))
    hooked.bind(trace.n, trace.m)
    assert not cgm_jax.wants_device_cgm(hooked, trace, model)
    # the catalog size no longer gates the path — only the padded hot
    # capacity does; big-catalog traces are admitted as long as their
    # window working set keeps h under MAX_DEVICE_CGM_HOT
    big = synth_trace(SynthConfig(
        kind="netflix", n_items=4 * 256 + 8, n_servers=4,
        n_requests=40, t_max=2.0, seed=0))
    big_env = CacheEnvironment.resolve(None, big, pol.params)
    big_model = get_cost_model("table1", big_env)
    assert cgm_jax.wants_device_cgm(pol, big, big_model)
    # ... but an oversized hot capacity falls back in auto mode
    monkeypatch.setattr(cgm_jax, "MAX_DEVICE_CGM_HOT", 8)
    assert not cgm_jax.wants_device_cgm(pol, big, big_model)
    monkeypatch.setenv("REPRO_JAX_CGM", "force")
    assert cgm_jax.wants_device_cgm(pol, big, big_model)
    monkeypatch.delenv("REPRO_JAX_CGM")
    monkeypatch.undo()
    # non-prune approximate-merge lanes still need the (2n, 2n) merge
    # space, so they stay small-catalog only (w/o-CS ablation regime)
    soft = get_policy("akpc", **_kw(0.2, 0.4, 4))
    soft.bind(big.n, big.m)
    assert not cgm_jax.wants_device_cgm(soft, big, big_model)
    soft.bind(trace.n, trace.m)
    assert cgm_jax.wants_device_cgm(soft, trace, model)


def test_merge_density_kernel_matches_jnp_interpret():
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.kernels.merge_step import merge_density

    rng = np.random.default_rng(0)
    with enable_x64():
        for S, omega, gamma in [(16, 4, 0.5), (120, 6, 0.8), (257, 3, 0.34)]:
            B = rng.integers(0, 4, (S, S)).astype(np.float32)
            X = B + B.T
            np.fill_diagonal(X, rng.integers(0, 20, S) * 2)
            sizes = rng.integers(0, omega, S).astype(np.int32)
            Xj, sj = jnp.asarray(X), jnp.asarray(sizes)
            om = jnp.asarray(omega, jnp.int32)
            gm = jnp.asarray(gamma, jnp.float32)
            D_k = np.asarray(merge_density(Xj, sj, om, gm, interpret=True))
            within = jnp.diag(Xj) / 2.0
            e_u = (within[:, None] + within[None, :]) + Xj
            okp = ((sj[:, None] + sj[None, :]) == om) & ~jnp.eye(S, dtype=bool)
            om_f = jnp.asarray(omega, jnp.float64)
            e_max = (om_f * (om_f - 1.0) / 2.0).astype(jnp.float32)
            dens = jnp.where(okp, e_u / e_max, -1.0)
            D_r = np.asarray(jnp.where(dens >= gm, dens, -1.0))
            assert np.array_equal(D_k, D_r), (S, omega, gamma)


def test_device_cgm_with_kernels_interpret(trace):
    """The in-trace Pallas path (crm_update + clique_pair_edges +
    merge_density, interpret mode on CPU) is cost- and partition-identical
    to the host."""
    pol = get_policy("akpc", **_kw(0.2, 0.85, 4))
    pol.bind(trace.n, trace.m)
    env = CacheEnvironment.resolve(None, trace, pol.params)
    jeng = JaxReplayEngine(trace.n, trace.m, pol.params, env=env)
    sched = cgm_jax.build_cgm_schedule(trace, T_CG, uses_sizes=False)
    cspec = cgm_jax.cgm_spec(pol.config, pol.config.params, trace.n)
    carry0 = cgm_jax.init_cgm_carry(
        jeng.engine.state, None, None, n=trace.n, m=trace.m,
        uses_sizes=False, item_sizes=None, schedule=sched)
    final, _ = cgm_jax.run_cgm_schedule(
        sched, jeng._spec, jeng._statics, cspec, carry0, None,
        use_kernels=True)
    ref = run_policy(get_policy("akpc", **_kw(0.2, 0.85, 4)), trace)
    part = cgm_jax.partition_from_of(trace.n, final["of"])
    assert np.array_equal(part.sizes(), ref.clique_sizes)
    acc = final["acc"]
    d = ref.costs.as_dict()
    assert np.isclose(acc[0], d["transfer"], rtol=1e-9)
    assert np.isclose(acc[1], d["caching"], rtol=1e-9)
    assert int(acc[3]) == d["n_misses"]


# ---------------------------------------------------------------------------
# compact hot space beyond the old 256-item cap (DESIGN.md §15)
# ---------------------------------------------------------------------------
N_BIG = 4096
T_CG_BIG = 2.0


@pytest.fixture(scope="module")
def big_trace():
    return synth_trace(SynthConfig(
        kind="spotify", n_items=N_BIG, n_servers=12, n_requests=1500,
        t_max=8.0, bundle_cover=1.0, bundle_zipf=0.7, seed=3))


def test_big_catalog_chained_parity_vs_oracle(big_trace):
    """n=4096 — far beyond the old MAX_DEVICE_CGM_N = 256 cap: the
    compact hot-space boundary reproduces the cliques_ref oracle
    element-for-element at every chained window."""
    trace = big_trace
    pol = get_policy("akpc", params=CostParams(theta=0.2, gamma=0.85,
                                               omega=4),
                     t_cg=T_CG_BIG, top_frac=TOP_FRAC)
    pol.bind(trace.n, trace.m)
    env = CacheEnvironment.resolve(None, trace, pol.params)
    jeng = JaxReplayEngine(trace.n, trace.m, pol.params, env=env)
    sched = cgm_jax.build_cgm_schedule(
        trace, T_CG_BIG, uses_sizes=False,
        hot_dims=cgm_jax.policy_hot_dims(pol))
    assert sched.boundary_steps.size >= 3          # chained windows
    assert sched.h < trace.n                       # genuinely compact
    cspec = cgm_jax.cgm_spec(pol.config, pol.config.params, trace.n)
    carry0 = cgm_jax.init_cgm_carry(
        jeng.engine.state, None, None, n=trace.n, m=trace.m,
        uses_sizes=False, item_sizes=None, schedule=sched)
    final, ofs = cgm_jax.run_cgm_schedule(
        sched, jeng._spec, jeng._statics, cspec, carry0, None)
    want = _oracle_trajectory(trace, 0.2, 0.85, 4, t_cg=T_CG_BIG)
    assert len(want) == sched.boundary_steps.size
    for w, (b, ref_of) in enumerate(zip(sched.boundary_steps, want)):
        assert np.array_equal(ofs[int(b)], ref_of), f"window={w}"
    assert np.array_equal(final["of"], want[-1])


@pytest.mark.parametrize("layout_kind", ["dense", "bucketed"])
def test_big_catalog_layouts_route_device(big_trace, layout_kind):
    """run_policy_jax keeps the CGM on device at n=4096 under both the
    dense and the bucketed StateLayout, and the final partition still
    matches the frozen oracle."""
    from repro.core.state_layout import StateLayout

    layout = None if layout_kind == "dense" else StateLayout(
        kind="bucketed")
    trace = big_trace
    pol = get_policy("akpc", params=CostParams(theta=0.2, gamma=0.85,
                                               omega=4),
                     t_cg=T_CG_BIG, top_frac=TOP_FRAC)
    before = cliques_mod.CGM_CALLS
    got = run_policy_jax(pol, trace, layout=layout)
    assert cliques_mod.CGM_CALLS == before          # zero host CGM calls
    want = _oracle_trajectory(trace, 0.2, 0.85, 4, t_cg=T_CG_BIG)
    assert np.array_equal(
        got.clique_sizes, np.bincount(want[-1]).astype(np.int64))


def test_wants_device_cgm_accepts_ten_k_catalog():
    """The ISSUE-10 acceptance bar: auto-routing admits 10^4 items."""
    from repro.core.cost import get_cost_model

    big = synth_trace(SynthConfig(
        kind="netflix", n_items=10_000, n_servers=8, n_requests=60,
        t_max=2.0, seed=0))
    pol = get_policy("akpc", **_kw(0.2, 0.85, 4))
    pol.bind(big.n, big.m)
    env = CacheEnvironment.resolve(None, big, pol.params)
    model = get_cost_model("table1", env)
    assert cgm_jax.wants_device_cgm(pol, big, model)


def test_window_crm_f32_exact_guard():
    """Co-occurrence counts live in f32: a window capacity at 2**24
    must be refused BEFORE any tracing (counts could silently lose
    integer exactness), while wcap just below the bound traces fine —
    checked abstractly so no (2**24, d) buffer is ever allocated."""
    import jax.numpy as jnp

    with pytest.raises(ValueError, match="f32"):
        cgm_jax._window_crm_device(
            None, None, n=8, h=4, wcap=cgm_jax._F32_EXACT,
            use_kernels=False)

    n, h, dbuf = 8, 4, 2
    wcap = cgm_jax._F32_EXACT - 1
    carry = {
        "wcnt": jax.ShapeDtypeStruct((n + 1,), jnp.int32),
        "wbuf": jax.ShapeDtypeStruct((wcap, dbuf), jnp.int32),
        "wlen": jax.ShapeDtypeStruct((), jnp.int32),
    }
    cspec = {
        "theta": jax.ShapeDtypeStruct((), jnp.float32),
        "top_frac": jax.ShapeDtypeStruct((), jnp.float64),
        "of_catalog": jax.ShapeDtypeStruct((), jnp.bool_),
    }
    out = jax.eval_shape(
        lambda c, s: cgm_jax._window_crm_device(
            c, s, n=n, h=h, wcap=wcap, use_kernels=False),
        carry, cspec)
    assert out[3].shape == (h, h)                  # raw CRM
    assert out[5].shape == (h, h)                  # binary CRM
