"""Alg. 3/4 — clique partition invariants + split/merge behaviour."""
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cliques_ref
from repro.core.cliques import (
    CliquePartition,
    _CrmView,
    generate_cliques,
    split_oversized,
)
from repro.core.crm import build_window_crm


def _window(rng, n, reqs, d_max=5):
    items = np.full((reqs, d_max), -1, np.int32)
    for r in range(reqs):
        k = rng.integers(1, d_max + 1)
        items[r, :k] = rng.choice(n, size=k, replace=False)
    return items


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_partition_invariant(seed):
    """Every item belongs to exactly one clique, sizes <= omega."""
    rng = np.random.default_rng(seed)
    n, omega = 30, 5
    crm = build_window_crm(_window(rng, n, 60), n, theta=0.15, top_frac=1.0)
    part = generate_cliques(None, None, crm, n, omega, gamma=0.85)
    seen = np.zeros(n, int)
    for c in part.cliques:
        assert 1 <= len(c) <= omega
        for d in c:
            seen[d] += 1
    assert (seen == 1).all()
    assert (part.clique_of >= 0).all()
    for i, c in enumerate(part.cliques):
        for d in c:
            assert part.clique_of[d] == i


def test_split_oversized():
    """A fully-connected 8-group must split into parts <= omega."""
    n = 8
    items = np.array([list(range(8))], np.int32).repeat(10, 0)
    crm = build_window_crm(items, n, theta=0.01, top_frac=1.0)
    part = generate_cliques(None, None, crm, n, omega=5, gamma=0.85)
    sizes = sorted(len(c) for c in part.cliques)
    assert max(sizes) <= 5 and sum(sizes) == n


def test_approximate_merge_density():
    """gamma=0.85, omega=5: a 5-group with 9/10 edges merges, 7/10 doesn't."""
    n = 10
    reqs = []
    # group A {0..4}: all pairs except (3,4)  -> 9 edges
    for a in range(5):
        for b in range(a + 1, 5):
            if (a, b) != (3, 4):
                reqs.append([a, b])
    # group B {5..9}: only 7 of 10 edges
    eb = [(5, 6), (5, 7), (5, 8), (5, 9), (6, 7), (6, 8), (7, 8)]
    reqs.extend([list(e) for e in eb])
    items = np.full((len(reqs), 2), -1, np.int32)
    for i, r in enumerate(reqs):
        items[i] = r
    crm = build_window_crm(items, n, theta=0.0, top_frac=1.0)
    part = generate_cliques(None, None, crm, n, omega=5, gamma=0.85)
    groups = {tuple(sorted(c)) for c in part.cliques if len(c) == 5}
    assert (0, 1, 2, 3, 4) in groups
    assert (5, 6, 7, 8, 9) not in groups


def test_incremental_reuse():
    """Unchanged CRM -> unchanged partition (Alg. 4 reuse)."""
    rng = np.random.default_rng(0)
    n = 20
    crm = build_window_crm(_window(rng, n, 50), n, theta=0.2, top_frac=1.0)
    p1 = generate_cliques(None, None, crm, n, 5, 0.85)
    p2 = generate_cliques(p1, crm, crm, n, 5, 0.85)
    assert p1.canonical() == p2.canonical()


# ---------------------------------------------------------------------------
# from_cliques validation (empty groups / bad ids silently corrupted the
# engine's size-dependent transfer/rent math before PR 3)
# ---------------------------------------------------------------------------
def test_from_cliques_rejects_empty_group():
    with pytest.raises(ValueError, match="empty clique group"):
        CliquePartition.from_cliques(5, [(0, 1), ()])


def test_from_cliques_rejects_out_of_range_ids():
    with pytest.raises(ValueError, match="outside"):
        CliquePartition.from_cliques(5, [(0, 5)])
    with pytest.raises(ValueError, match="outside"):
        CliquePartition.from_cliques(5, [(-1, 2)])


def test_from_cliques_rejects_duplicates():
    with pytest.raises(ValueError, match="in two cliques"):
        CliquePartition.from_cliques(6, [(0, 1), (1, 2)])
    with pytest.raises(ValueError, match="in two cliques"):
        CliquePartition.from_cliques(6, [(3, 3)])


def test_from_cliques_valid_roundtrip():
    part = CliquePartition.from_cliques(6, [(4, 1), (2, 3)])
    assert part.cliques[:2] == [(1, 4), (2, 3)]
    assert sorted(part.cliques[2:]) == [(0,), (5,)]
    assert (part.sizes() == np.array([2, 2, 1, 1])).all()


# ---------------------------------------------------------------------------
# packed array-native layout (shared with session.pack_partition)
# ---------------------------------------------------------------------------
def test_packed_layout():
    part = CliquePartition.from_cliques(7, [(2, 0, 5), (3, 6)])
    want = np.array(
        [[0, 2, 5], [3, 6, -1], [1, -1, -1], [4, -1, -1]], np.int64
    )
    assert (part.packed() == want).all()
    from repro.core.session import pack_partition, unpack_partition

    assert (pack_partition(part) == want).all()
    back = unpack_partition(7, pack_partition(part))
    assert back.cliques == part.cliques
    assert (back.clique_of == part.clique_of).all()


# ---------------------------------------------------------------------------
# split_oversized: iterative worklist (the oracle recursion overflows)
# ---------------------------------------------------------------------------
def _cold_views(n):
    """Fast + oracle views over a CRM whose hot set is {0, 1} only."""
    crm = build_window_crm(np.array([[0, 1]], np.int32), n, theta=0.0,
                           top_frac=1.0)
    return _CrmView(crm, n), cliques_ref._CrmView(crm, n)


def test_split_oversized_5000_members_omega4():
    """A 5000-member group (e.g. via run_policy(initial_partition=...))
    must split without RecursionError and cover every member."""
    n = 6000
    view, _ = _cold_views(n)
    big = tuple(range(2, 5002))
    parts = split_oversized(big, 4, view)
    assert max(len(p) for p in parts) <= 4
    assert sorted(d for p in parts for d in p) == list(big)

    # end to end: the oversized group arrives through a previous partition
    prev = CliquePartition.from_cliques(n, [big])
    crm = build_window_crm(np.array([[0, 1]], np.int32), n, theta=0.0,
                           top_frac=1.0)
    part = generate_cliques(prev, None, crm, n, omega=4, gamma=0.85)
    assert int(part.sizes().max()) <= 4
    assert (np.sort(np.concatenate([np.array(c) for c in part.cliques]))
            == np.arange(n)).all()


def test_split_oversized_matches_oracle_and_oracle_recurses():
    """Worklist == recursive oracle where the oracle survives; the oracle's
    one-stack-frame-per-split recursion dies once peels exceed the limit."""
    n = 400
    view, oview = _cold_views(n)
    group = tuple(range(2, 202))        # 200 cold members
    for omega in (3, 4, 9):
        assert (split_oversized(group, omega, view)
                == cliques_ref.split_oversized(group, omega, oview))
    import inspect

    limit = sys.getrecursionlimit()
    try:
        # headroom far below the ~200 frames the oracle's peel recursion
        # needs, but comfortably above what the worklist + numpy use
        sys.setrecursionlimit(len(inspect.stack()) + 100)
        with pytest.raises(RecursionError):
            cliques_ref.split_oversized(group, 4, oview)
        assert len(split_oversized(group, 4, view)) == 197
    finally:
        sys.setrecursionlimit(limit)


def test_split_oversized_hot_group_matches_oracle():
    """Weakest-edge search + weighted sides on a fully hot group."""
    rng = np.random.default_rng(2)
    n = 30
    crm = build_window_crm(_window(rng, n, 150), n, theta=0.05, top_frac=1.0)
    view = _CrmView(crm, n)
    oview = cliques_ref._CrmView(crm, n)
    g = tuple(range(n))
    for omega in (3, 5, 11):
        assert (split_oversized(g, omega, view)
                == cliques_ref.split_oversized(g, omega, oview))
