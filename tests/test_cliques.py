"""Alg. 3/4 — clique partition invariants + split/merge behaviour."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cliques import CliquePartition, generate_cliques
from repro.core.crm import build_window_crm


def _window(rng, n, reqs, d_max=5):
    items = np.full((reqs, d_max), -1, np.int32)
    for r in range(reqs):
        k = rng.integers(1, d_max + 1)
        items[r, :k] = rng.choice(n, size=k, replace=False)
    return items


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_partition_invariant(seed):
    """Every item belongs to exactly one clique, sizes <= omega."""
    rng = np.random.default_rng(seed)
    n, omega = 30, 5
    crm = build_window_crm(_window(rng, n, 60), n, theta=0.15, top_frac=1.0)
    part = generate_cliques(None, None, crm, n, omega, gamma=0.85)
    seen = np.zeros(n, int)
    for c in part.cliques:
        assert 1 <= len(c) <= omega
        for d in c:
            seen[d] += 1
    assert (seen == 1).all()
    assert (part.clique_of >= 0).all()
    for i, c in enumerate(part.cliques):
        for d in c:
            assert part.clique_of[d] == i


def test_split_oversized():
    """A fully-connected 8-group must split into parts <= omega."""
    n = 8
    items = np.array([list(range(8))], np.int32).repeat(10, 0)
    crm = build_window_crm(items, n, theta=0.01, top_frac=1.0)
    part = generate_cliques(None, None, crm, n, omega=5, gamma=0.85)
    sizes = sorted(len(c) for c in part.cliques)
    assert max(sizes) <= 5 and sum(sizes) == n


def test_approximate_merge_density():
    """gamma=0.85, omega=5: a 5-group with 9/10 edges merges, 7/10 doesn't."""
    n = 10
    reqs = []
    # group A {0..4}: all pairs except (3,4)  -> 9 edges
    for a in range(5):
        for b in range(a + 1, 5):
            if (a, b) != (3, 4):
                reqs.append([a, b])
    # group B {5..9}: only 7 of 10 edges
    eb = [(5, 6), (5, 7), (5, 8), (5, 9), (6, 7), (6, 8), (7, 8)]
    reqs.extend([list(e) for e in eb])
    items = np.full((len(reqs), 2), -1, np.int32)
    for i, r in enumerate(reqs):
        items[i] = r
    crm = build_window_crm(items, n, theta=0.0, top_frac=1.0)
    part = generate_cliques(None, None, crm, n, omega=5, gamma=0.85)
    groups = {tuple(sorted(c)) for c in part.cliques if len(c) == 5}
    assert (0, 1, 2, 3, 4) in groups
    assert (5, 6, 7, 8, 9) not in groups


def test_incremental_reuse():
    """Unchanged CRM -> unchanged partition (Alg. 4 reuse)."""
    rng = np.random.default_rng(0)
    n = 20
    crm = build_window_crm(_window(rng, n, 50), n, theta=0.2, top_frac=1.0)
    p1 = generate_cliques(None, None, crm, n, 5, 0.85)
    p2 = generate_cliques(p1, crm, crm, n, 5, 0.85)
    assert p1.canonical() == p2.canonical()
