"""Checkpoint roundtrip (incl. bf16), commit marker, manager GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"w": jnp.ones((4,), jnp.bfloat16) * 1.5,
              "step": jnp.array(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t, meta={"note": "x"})
    restored, meta = restore_checkpoint(str(tmp_path), 3, t)
    assert meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_commit_marker_protects_torn_writes(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a torn write: step dir without marker
    os.makedirs(tmp_path / "step_000000002")
    assert latest_step(str(tmp_path)) == 1


def test_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 4
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(steps) == 2


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    with pytest.raises(AssertionError):
        restore_checkpoint(str(tmp_path), 1, {"different": jnp.zeros(1)})
