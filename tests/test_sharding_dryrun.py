"""Sharding rules + a mini multi-device dry-run (subprocess: own device count)."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.launch.sharding import batch_spec, cache_spec, param_spec
from jax.sharding import PartitionSpec as P


class _Leaf:
    def __init__(self, shape):
        self.shape = shape


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class _K:
    def __init__(self, key):
        self.key = key


def test_param_rules():
    mesh = _FakeMesh()
    assert param_spec((_K("embed"),), _Leaf((102400, 5120)), mesh) == P("model", "data")
    assert param_spec((_K("attn"), _K("wq")), _Leaf((60, 5120, 16384)), mesh) == \
        P(None, "data", "model")
    assert param_spec((_K("attn"), _K("wo")), _Leaf((60, 16384, 5120)), mesh) == \
        P(None, "model", "data")
    # expert weights: E over model, d over data
    assert param_spec((_K("mlp"), _K("wi")), _Leaf((60, 160, 5120, 1536)), mesh) == \
        P(None, "model", "data", None)
    # indivisible dims fall back to replication
    assert param_spec((_K("attn"), _K("wq")), _Leaf((4, 30, 30)), mesh) == P(None, None, None)
    assert param_spec((_K("norm1"),), _Leaf((60, 5120)), mesh) == P()


def test_cache_rules():
    mesh = _FakeMesh()
    # KV cache: batch over dp, seq over model
    assert cache_spec((_K("k"),), _Leaf((40, 128, 32768, 8, 128)), mesh) == \
        P(None, ("data",), "model", None, None)
    # batch=1 long-context: seq over data+model (context parallel)
    assert cache_spec((_K("k"),), _Leaf((24, 1, 524288, 8, 128)), mesh) == \
        P(None, None, ("data", "model"), None, None)


MINI = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro.launch.specs import build_cell, lower_cell
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh()
cell = build_cell("qwen2_5_3b", "decode_32k", mesh)
comp = lower_cell(cell, mesh).compile()
ma = comp.memory_analysis()
print(json.dumps({"ok": True, "temp": ma.temp_size_in_bytes}))
"""


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", MINI], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))),
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
